//! Batched decode kernels: the [`AttentionKernel`] trait and its five
//! backends (fp16, lookat, scalar-quant, pjrt-fp16, pjrt-lookat).
//!
//! The engine builds one [`DecodePlan`] per layer per batcher tick —
//! every (seq, head) of the drained batch at once — and hands it to the
//! kernel. Since the chunked-prefill scheduler landed, a work item is a
//! *span* of `rows ≥ 1` query rows: decode items carry one row, prefill
//! chunks carry the whole chunk. Row `r` of an item attends only its
//! causal prefix — `seq_len - rows + r + 1` cached tokens, or the
//! explicit per-row survivor counts in [`WorkItem::prefixes`] when the
//! engine's L2-norm pruning policy skipped appends — so prefill compute
//! rides the same block-resident scan as decode and a chunk of any size
//! is bit-identical to the monolithic equivalent: every row's math
//! depends only on (query row, cache prefix), never on how the rows
//! were grouped into ticks. The PJRT kernels derive prefixes from the
//! cache length only (the engine rejects pruning policies on PJRT
//! backends, where the two derivations always agree).
//!
//! The pure-rust kernels fan the independent items out on
//! `util::threadpool`; the PJRT kernels own the runtime client (whose
//! handles are not `Send`) and walk the plan's per-sequence groups
//! serially, packing padded artifact calls exactly as the old per-seq
//! path did (one call per query row, masked to the row's prefix).
//!
//! The LOOKAT kernel is the paper's bandwidth story end-to-end: it
//! builds the LUT per query row, fast-scans the PQ codes *in place*
//! over the cache's head-major, subspace-major-interleaved block lanes
//! ([`LookupTable::scores_lanes`]) and accumulates α·V straight from
//! the same views — zero per-step key-code copies, and one LUT row hot
//! per subspace while a block's codes stream. Because prefill rides
//! this same path, a preempted sequence re-prefills by re-encoding
//! codes only: the resumed decode states are bit-identical to the
//! uninterrupted run.
//!
//! Every pure-rust kernel is additionally *value-storage aware*: when
//! the plan's cache stores PQ-coded values
//! ([`crate::kvcache::ValueStorage::Pq`]), the attention tail switches
//! to the fused blocked weighted decode
//! ([`finish_attention_kv_blocks`]) — post-softmax weights are
//! scatter-accumulated into per-subspace tables while the value-code
//! blocks stream, so values are never dequantized per token either.
//! LOOKAT keys × PQ values is the paper's fully-compressed "lookat-kv"
//! combination with zero per-step copies on *both* cache sides.

use anyhow::{bail, Context};

use super::{
    finish_attention, finish_attention_blocks,
    finish_attention_kv_blocks, AttnOutput,
};
use crate::kvcache::{CacheError, KvCache, SeqId};
use crate::pq::LookupTable;
use crate::runtime::{InputArg, Runtime};
use crate::util::threadpool::{parallel_try_map, scratch};
use crate::util::timing::{timed, Phase, PhaseTimers};

/// One (seq, head) attention task of a decode tick: `rows` query rows
/// over one head's cache. Decode items have `rows == 1`; prefill-chunk
/// items carry the chunk's full span.
pub struct WorkItem<'a> {
    pub seq: SeqId,
    pub head: usize,
    /// this head's query rows, (rows × d_k) row-major
    pub q: &'a [f32],
    /// query rows in this item; row `r` attends the causal prefix of
    /// `seq_len - rows + r + 1` cached tokens (the span's K/V are
    /// appended to the cache before the kernel runs)
    pub rows: usize,
    /// per-row causal prefix lengths, when the appends that preceded
    /// this plan decided them (the prune-aware path: a pruned token
    /// leaves the cache length unchanged, so row `r`'s prefix is the
    /// *survivor* count after its append attempt, not
    /// `seq_len - rows + r + 1`). `None` derives the classic uniform
    /// prefixes from the cache length — with pruning off the two are
    /// equal, so this field never changes results, only feasibility.
    pub prefixes: Option<&'a [usize]>,
}

impl WorkItem<'_> {
    /// Causal prefix length of row `r` against a cache of `n` tokens.
    fn prefix(&self, n: usize, r: usize) -> usize {
        match self.prefixes {
            Some(ps) => ps[r].min(n),
            None => row_prefix(n, self.rows, r),
        }
    }
}

/// All attention work of one layer for one decode tick.
///
/// Items are seq-major: the engine emits every head of a sequence
/// consecutively, heads ascending, all heads of a sequence sharing one
/// `rows` — the PJRT kernels rely on this to regroup items into padded
/// artifact calls per sequence.
pub struct DecodePlan<'a> {
    /// the layer's cache; every item resolves against it
    pub cache: &'a KvCache,
    pub d_k: usize,
    /// worker threads to fan items out on (1 = serial)
    pub threads: usize,
    /// optional per-phase timing sink (`lut_build` / `scan` /
    /// `value_decode`); `None` skips all clock reads
    pub timers: Option<&'a PhaseTimers>,
    pub items: Vec<WorkItem<'a>>,
}

impl DecodePlan<'_> {
    /// Total output rows the kernel must produce (Σ item rows).
    pub fn total_rows(&self) -> usize {
        self.items.iter().map(|it| it.rows).sum()
    }
}

/// A batched attention backend: scores and attends every (seq, head)
/// item of a [`DecodePlan`], returning one [`AttnOutput`] per (item,
/// row) — item-major, rows ascending within an item.
pub trait AttentionKernel {
    /// Kernel name (diagnostics / reports).
    fn name(&self) -> &'static str;

    /// Run the whole plan. Outputs align with `plan.items` flattened
    /// over each item's rows.
    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>;
}

std::thread_local! {
    /// Per-thread gather scratch (keys, values) for the dense kernels:
    /// two allocations per fan-out worker instead of two per (seq,
    /// head) item. Fan-out runs on `util::threadpool`'s persistent
    /// process-wide pool, so workers — and this scratch — survive
    /// across decode ticks; the serial (threads = 1) path carries its
    /// capacity on the engine thread the same way.
    static GATHER_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<f32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

/// Raw (unscaled) dense scores of one query against gathered keys,
/// into a buffer leased from the scratch arena (recycled by the
/// serving loop once the weights are consumed).
fn dense_scores(q: &[f32], keys: &[f32], n: usize) -> Vec<f32> {
    let d_k = q.len();
    let mut out = scratch().take_f32_any(n);
    for (l, o) in out.iter_mut().enumerate() {
        *o = crate::tensor::dot(q, &keys[l * d_k..(l + 1) * d_k]);
    }
    out
}

/// Shared attention tail for one plan row given its raw prefix scores:
/// block-resident α·V over raw values, or the fused blocked weighted
/// decode when the cache stores PQ-coded values. The block stream may
/// extend past `scores.len()` tokens (span rows attend a prefix); the
/// tails truncate it. Booked as the `value_decode` phase.
fn finish_item(
    plan: &DecodePlan<'_>,
    it: &WorkItem<'_>,
    scores: Vec<f32>,
) -> Result<AttnOutput, CacheError> {
    let blocks = plan.cache.blocks(it.seq, it.head)?;
    Ok(timed(plan.timers, Phase::ValueDecode, || {
        match plan.cache.value_codecs() {
            None => finish_attention_blocks(scores, blocks, plan.d_k),
            Some(vcodecs) => finish_attention_kv_blocks(
                scores,
                blocks,
                &vcodecs[it.head],
                plan.d_k,
            ),
        }
    }))
}

/// Causal prefix length of row `r` of an item whose sequence currently
/// caches `n` tokens (the span was appended before the kernel ran).
fn row_prefix(n: usize, rows: usize, r: usize) -> usize {
    debug_assert!(rows >= 1 && rows <= n);
    n - rows + r + 1
}

/// Flatten per-item output vectors into the plan's (item, row) order.
fn flatten_rows(per_item: Vec<Vec<AttnOutput>>) -> Vec<AttnOutput> {
    per_item.into_iter().flatten().collect()
}

/// Exact attention over FP16-stored keys (gathers the paged cache into
/// contiguous scratch per item — dense scoring needs one flat tensor).
/// With PQ-coded values, only the keys are gathered; the value side
/// runs the fused blocked weighted decode.
pub struct Fp16Kernel;

impl AttentionKernel for Fp16Kernel {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let pq_values = plan.cache.value_codecs().is_some();
        let d_k = plan.d_k;
        let per_item = parallel_try_map(
            plan.items.len(),
            plan.threads,
            |i| {
                let it = &plan.items[i];
                let n = plan.cache.seq_len(it.seq)?;
                GATHER_SCRATCH.with(|s| {
                    let (keys, vals) = &mut *s.borrow_mut();
                    plan.cache.gather_keys_into(it.seq, it.head, keys)?;
                    if !pq_values {
                        plan.cache
                            .gather_values_into(it.seq, it.head, vals)?;
                    }
                    let mut outs = Vec::with_capacity(it.rows);
                    for r in 0..it.rows {
                        let p = it.prefix(n, r);
                        let q = &it.q[r * d_k..(r + 1) * d_k];
                        let scores =
                            timed(plan.timers, Phase::Scan, || {
                                dense_scores(q, &keys[..p * d_k], p)
                            });
                        if pq_values {
                            outs.push(finish_item(plan, it, scores)?);
                        } else {
                            outs.push(timed(
                                plan.timers,
                                Phase::ValueDecode,
                                || {
                                    finish_attention(
                                        scores,
                                        &vals[..p * d_k],
                                        d_k,
                                    )
                                },
                            ));
                        }
                    }
                    Ok::<_, CacheError>(outs)
                })
            },
        )
        .map_err(|e: CacheError| anyhow::anyhow!("fp16 decode: {e}"))?;
        Ok(flatten_rows(per_item))
    }
}

/// INT4/INT8 round-trip baseline (gathers, dequantizes, then scores —
/// the bandwidth-bound path the paper compares against). The per-tensor
/// scale is computed over each row's causal prefix, exactly as the
/// single-row decode path sees it, so span rows stay bit-identical to
/// their decode-tick equivalents. With PQ-coded values this is the
/// "int-key × pq-value" combination: round-tripped key scores feed the
/// fused blocked weighted decode.
pub struct ScalarQuantKernel {
    pub bits: u8,
}

impl AttentionKernel for ScalarQuantKernel {
    fn name(&self) -> &'static str {
        "scalar-quant"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let bits = self.bits;
        let pq_values = plan.cache.value_codecs().is_some();
        let d_k = plan.d_k;
        let per_item = parallel_try_map(
            plan.items.len(),
            plan.threads,
            |i| {
                let it = &plan.items[i];
                let n = plan.cache.seq_len(it.seq)?;
                GATHER_SCRATCH.with(|s| {
                    let (keys, vals) = &mut *s.borrow_mut();
                    plan.cache.gather_keys_into(it.seq, it.head, keys)?;
                    if !pq_values {
                        plan.cache
                            .gather_values_into(it.seq, it.head, vals)?;
                    }
                    let mut outs = Vec::with_capacity(it.rows);
                    for r in 0..it.rows {
                        let p = it.prefix(n, r);
                        let q = &it.q[r * d_k..(r + 1) * d_k];
                        // the round-trip + dense rescore is the scan
                        // phase of this bandwidth-bound baseline
                        let scores =
                            timed(plan.timers, Phase::Scan, || {
                                let deq =
                                    crate::quant::quant_roundtrip(
                                        &keys[..p * d_k],
                                        bits,
                                    );
                                dense_scores(q, &deq, p)
                            });
                        if pq_values {
                            outs.push(finish_item(plan, it, scores)?);
                        } else {
                            outs.push(timed(
                                plan.timers,
                                Phase::ValueDecode,
                                || {
                                    finish_attention(
                                        scores,
                                        &vals[..p * d_k],
                                        d_k,
                                    )
                                },
                            ));
                        }
                    }
                    Ok::<_, CacheError>(outs)
                })
            },
        )
        .map_err(|e: CacheError| {
            anyhow::anyhow!("int{bits} decode: {e}")
        })?;
        Ok(flatten_rows(per_item))
    }
}

/// LOOKAT ADC over the block-resident PQ codes: LUT build per query
/// row, then a subspace-major fast scan ([`LookupTable::scores_lanes`])
/// and α·V accumulated straight from the cache's
/// [`crate::kvcache::BlockView`]s — no gather copies at all. The scan
/// walks one LUT row per subspace over each block's code lane, so the
/// hot (K,) row stays register/L1-resident while the uint8 codes
/// stream. All per-row scratch (the LUT table, the scores buffer) is
/// leased from the thread pool's [`crate::util::threadpool::ScratchPool`]
/// and recycled, so steady-state ticks allocate nothing here. With
/// PQ-coded values this is the paper's fully-compressed **lookat-kv**
/// path: both the key-code scan and the value weighted decode are
/// block-resident, zero per-step copies on either cache side.
pub struct LookatKernel;

impl AttentionKernel for LookatKernel {
    fn name(&self) -> &'static str {
        "lookat"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let codecs = plan
            .cache
            .codecs()
            .context("lookat kernel needs a PQ cache")?
            .clone();
        // K ≤ 16 codecs store nibble-packed block lanes; scan them with
        // the register-resident shuffle kernel
        let packed = codecs[0].packed();
        let d_k = plan.d_k;
        let per_item = parallel_try_map(
            plan.items.len(),
            plan.threads,
            |i| {
                let it = &plan.items[i];
                let n = plan.cache.seq_len(it.seq)?;
                let pool = scratch();
                let mut outs = Vec::with_capacity(it.rows);
                for r in 0..it.rows {
                    let p = it.prefix(n, r);
                    let q = &it.q[r * d_k..(r + 1) * d_k];
                    let lut = timed(plan.timers, Phase::LutBuild, || {
                        LookupTable::build_into(
                            q,
                            &codecs[it.head].codebook,
                            pool.take_f32(0),
                        )
                    });
                    let mut scores = pool.take_f32(0);
                    scores.reserve(p);
                    let blocks = plan.cache.blocks(it.seq, it.head)?;
                    // per-token ADC scores are independent, so cutting
                    // the lane stream at the row's causal prefix is
                    // exact — span rows never pay for tokens they
                    // would only truncate away
                    let mut left = p;
                    timed(plan.timers, Phase::Scan, || {
                        let lanes = blocks.filter_map(|b| {
                            if left == 0 {
                                return None;
                            }
                            let take = b.len.min(left);
                            left -= take;
                            Some((b.codes, take))
                        });
                        if packed {
                            lut.scores_lanes_packed(lanes, &mut scores)
                        } else {
                            lut.scores_lanes(lanes, &mut scores)
                        }
                    });
                    pool.put_f32(lut.into_table());
                    debug_assert_eq!(scores.len(), p);
                    outs.push(finish_item(plan, it, scores)?);
                }
                Ok::<_, CacheError>(outs)
            },
        )
        .map_err(|e: CacheError| anyhow::anyhow!("lookat decode: {e}"))?;
        Ok(flatten_rows(per_item))
    }
}

/// Smallest artifact length that fits `n` cached tokens.
fn pjrt_len_for(lens: &[usize], n: usize) -> anyhow::Result<usize> {
    lens.iter().copied().find(|&l| l >= n).with_context(|| {
        format!(
            "cache length {n} exceeds largest artifact L={:?}",
            lens.last()
        )
    })
}

/// Split a seq-major plan into per-sequence groups of `h` items and
/// check the ordering contract the engine promises (ascending heads,
/// one `rows` per sequence).
fn seq_groups<'p, 'a>(
    plan: &'p DecodePlan<'a>,
) -> anyhow::Result<std::slice::Chunks<'p, WorkItem<'a>>> {
    let h = plan.cache.h;
    if plan.items.len() % h != 0 {
        bail!(
            "DecodePlan has {} items, not a multiple of H={h}",
            plan.items.len()
        );
    }
    for group in plan.items.chunks(h) {
        for (j, it) in group.iter().enumerate() {
            if it.head != j
                || it.seq != group[0].seq
                || it.rows != group[0].rows
            {
                bail!("DecodePlan items must be seq-major with ascending \
                       heads and uniform rows per sequence");
            }
        }
    }
    Ok(plan.items.chunks(h))
}

/// Full-width (H · d_k) query rows of one sequence group, one per span
/// row, owned — the PJRT kernels need them after the plan borrow ends.
fn group_queries(
    group: &[WorkItem<'_>],
    h: usize,
    d_k: usize,
) -> (SeqId, usize, Vec<Vec<f32>>) {
    let rows = group[0].rows;
    let row_qs = (0..rows)
        .map(|r| {
            let mut q = vec![0.0f32; h * d_k];
            for it in group {
                q[it.head * d_k..(it.head + 1) * d_k]
                    .copy_from_slice(&it.q[r * d_k..(r + 1) * d_k]);
            }
            q
        })
        .collect();
    (group[0].seq, rows, row_qs)
}

/// Split one full-width context row (H · d_k) into per-head outputs.
/// PJRT artifacts return no attention distribution, so `weights` is
/// empty — the serving loop only consumes `out`.
///
/// `per_row` holds one full-width result per span row; the outputs are
/// emitted item-major (head-major, rows ascending within a head) to
/// match the kernel contract.
fn split_heads_rows(
    per_row: &[Vec<f32>],
    h: usize,
    d_k: usize,
    outs: &mut Vec<AttnOutput>,
) {
    for head in 0..h {
        for full in per_row {
            outs.push(AttnOutput {
                out: full[head * d_k..(head + 1) * d_k].to_vec(),
                weights: Vec::new(),
            });
        }
    }
}

/// FP16 attention through the AOT artifacts on the PJRT client. The
/// client's handles are not `Send`, so sequences run serially on the
/// engine thread; each span row is one padded artifact execution with
/// the mask cut to the row's causal prefix.
pub struct PjrtFp16Kernel {
    runtime: Runtime,
    lens: Vec<usize>,
    scratch_keys: Vec<f32>,
    scratch_vals: Vec<f32>,
}

impl PjrtFp16Kernel {
    pub fn new(runtime: Runtime, lens: Vec<usize>) -> Self {
        Self {
            runtime,
            lens,
            scratch_keys: Vec::new(),
            scratch_vals: Vec::new(),
        }
    }

    /// One padded artifact execution: `q` is (H · d_k), attention is
    /// masked to the first `prefix` of the sequence's `n` cached tokens.
    fn attend_seq(
        &mut self,
        cache: &KvCache,
        seq: SeqId,
        q: &[f32],
        prefix: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let (h, d_k) = (cache.h, cache.d_k);
        let n = cache.seq_len(seq).map_err(|e| anyhow::anyhow!("{e}"))?;
        let l = pjrt_len_for(&self.lens, n)?;
        // pack (H, L, d_k) padded keys/values + (L,) mask
        let mut k = vec![0.0f32; h * l * d_k];
        let mut v = vec![0.0f32; h * l * d_k];
        let mut mask = vec![0.0f32; l];
        mask[..prefix].fill(1.0);
        for head in 0..h {
            cache
                .gather_keys_into(seq, head, &mut self.scratch_keys)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            cache
                .gather_values_into(seq, head, &mut self.scratch_vals)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            k[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_keys);
            v[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_vals);
        }
        let name = format!("attn_fp16_L{l}");
        let outs = self.runtime.execute(
            &name,
            &[
                InputArg::F32(q),
                InputArg::F32(&k),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )?;
        Ok(outs.into_iter().next().unwrap())
    }
}

impl AttentionKernel for PjrtFp16Kernel {
    fn name(&self) -> &'static str {
        "pjrt-fp16"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let (h, d_k) = (plan.cache.h, plan.d_k);
        let groups: Vec<(SeqId, usize, Vec<Vec<f32>>)> = seq_groups(plan)?
            .map(|group| group_queries(group, h, d_k))
            .collect();
        let mut outs = Vec::with_capacity(plan.total_rows() * h);
        for (seq, rows, row_qs) in groups {
            let n = plan
                .cache
                .seq_len(seq)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let mut per_row = Vec::with_capacity(rows);
            for (r, q) in row_qs.iter().enumerate() {
                let prefix = row_prefix(n, rows, r);
                per_row.push(
                    self.attend_seq(plan.cache, seq, q, prefix)?);
            }
            split_heads_rows(&per_row, h, d_k, &mut outs);
        }
        Ok(outs)
    }
}

/// LOOKAT attention through the AOT artifacts on the PJRT client.
pub struct PjrtLookatKernel {
    runtime: Runtime,
    lens: Vec<usize>,
    m: usize,
    scratch_codes: Vec<u8>,
    scratch_vals: Vec<f32>,
}

impl PjrtLookatKernel {
    pub fn new(runtime: Runtime, lens: Vec<usize>, m: usize) -> Self {
        Self {
            runtime,
            lens,
            m,
            scratch_codes: Vec::new(),
            scratch_vals: Vec::new(),
        }
    }

    /// One padded artifact execution over the sequence's PQ codes,
    /// masked to the first `prefix` cached tokens.
    fn attend_seq(
        &mut self,
        cache: &KvCache,
        seq: SeqId,
        q: &[f32],
        prefix: usize,
    ) -> anyhow::Result<Vec<f32>> {
        let (h, d_k) = (cache.h, cache.d_k);
        let m = self.m;
        let codecs = cache
            .codecs()
            .context("pjrt-lookat kernel needs a PQ cache")?
            .clone();
        let n = cache.seq_len(seq).map_err(|e| anyhow::anyhow!("{e}"))?;
        let l = pjrt_len_for(&self.lens, n)?;
        let kk = codecs[0].codebook.k;
        let d_sub = d_k / m;
        let mut codes = vec![0i32; h * l * m];
        let mut cbs = vec![0.0f32; h * m * kk * d_sub];
        let mut v = vec![0.0f32; h * l * d_k];
        let mut mask = vec![0.0f32; l];
        mask[..prefix].fill(1.0);
        for head in 0..h {
            cache
                .gather_codes_into(seq, head, &mut self.scratch_codes)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            cache
                .gather_values_into(seq, head, &mut self.scratch_vals)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            for (i, &c) in self.scratch_codes.iter().enumerate() {
                codes[head * l * m + i] = c as i32;
            }
            v[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_vals);
            let flat = codecs[head].codebook.to_flat();
            cbs[head * m * kk * d_sub..(head + 1) * m * kk * d_sub]
                .copy_from_slice(&flat);
        }
        let name = format!("attn_lookat_m{m}_L{l}");
        let outs = self.runtime.execute(
            &name,
            &[
                InputArg::F32(q),
                InputArg::I32(&codes),
                InputArg::F32(&cbs),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )?;
        Ok(outs.into_iter().next().unwrap())
    }
}

impl AttentionKernel for PjrtLookatKernel {
    fn name(&self) -> &'static str {
        "pjrt-lookat"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let (h, d_k) = (plan.cache.h, plan.d_k);
        let groups: Vec<(SeqId, usize, Vec<Vec<f32>>)> = seq_groups(plan)?
            .map(|group| group_queries(group, h, d_k))
            .collect();
        let mut outs = Vec::with_capacity(plan.total_rows() * h);
        for (seq, rows, row_qs) in groups {
            let n = plan
                .cache
                .seq_len(seq)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let mut per_row = Vec::with_capacity(rows);
            for (r, q) in row_qs.iter().enumerate() {
                let prefix = row_prefix(n, rows, r);
                per_row.push(
                    self.attend_seq(plan.cache, seq, q, prefix)?);
            }
            split_heads_rows(&per_row, h, d_k, &mut outs);
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention;
    use crate::kvcache::{KeyStorage, KvCache, ValueStorage};
    use crate::pq::{PqCodec, TrainOpts};
    use crate::util::rng::Pcg32;

    const H: usize = 2;
    const DK: usize = 16;

    fn filled_cache_kv(
        storage: KeyStorage,
        values: ValueStorage,
        seqs: &[(SeqId, usize)],
    ) -> KvCache {
        let mut c = KvCache::new(H, DK, 64, storage, values);
        for &(id, n) in seqs {
            c.create_seq(id).unwrap();
            let mut rng = Pcg32::seed(0xC0 + id);
            for _ in 0..n {
                let k: Vec<f32> =
                    (0..H * DK).map(|_| rng.next_f32_std()).collect();
                let v: Vec<f32> =
                    (0..H * DK).map(|_| rng.next_f32_std()).collect();
                c.append(id, &k, &v).unwrap();
            }
        }
        c
    }

    fn filled_cache(storage: KeyStorage, seqs: &[(SeqId, usize)])
        -> KvCache
    {
        filled_cache_kv(storage, ValueStorage::Fp32, seqs)
    }

    fn trained_codecs(m: usize, seed: u64) -> Vec<PqCodec> {
        let mut rng = Pcg32::seed(seed);
        let calib: Vec<f32> =
            (0..256 * DK).map(|_| rng.next_f32_std()).collect();
        (0..H)
            .map(|_| {
                PqCodec::train(&calib, DK, m, 16, &TrainOpts::default())
            })
            .collect()
    }

    fn pq_storage(m: usize) -> KeyStorage {
        KeyStorage::pq(trained_codecs(m, 77)).unwrap()
    }

    fn pq_value_storage(m: usize) -> ValueStorage {
        ValueStorage::pq(trained_codecs(m, 78)).unwrap()
    }

    fn plan_for<'a>(
        cache: &'a KvCache,
        qs: &'a [Vec<f32>],
        seqs: &[SeqId],
        threads: usize,
    ) -> DecodePlan<'a> {
        let mut items = Vec::new();
        for (i, &seq) in seqs.iter().enumerate() {
            for head in 0..H {
                items.push(WorkItem {
                    seq,
                    head,
                    q: &qs[i][head * DK..(head + 1) * DK],
                    rows: 1,
                    prefixes: None,
                });
            }
        }
        DecodePlan { cache, d_k: DK, threads, timers: None, items }
    }

    fn queries(n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seed(seed);
        (0..n)
            .map(|_| (0..H * DK).map(|_| rng.next_f32_std()).collect())
            .collect()
    }

    #[test]
    fn fp16_kernel_matches_direct_attention() {
        let cache =
            filled_cache(KeyStorage::Fp16, &[(1, 40), (2, 70), (3, 5)]);
        let qs = queries(3, 9);
        let plan = plan_for(&cache, &qs, &[1, 2, 3], 2);
        let outs = Fp16Kernel.decode_batch(&plan).unwrap();
        assert_eq!(outs.len(), 6);
        for (j, it) in plan.items.iter().enumerate() {
            let mut keys = Vec::new();
            let mut vals = Vec::new();
            let n = cache
                .gather_keys_into(it.seq, it.head, &mut keys)
                .unwrap();
            cache.gather_values_into(it.seq, it.head, &mut vals).unwrap();
            let want = attention::exact_attention(it.q, &keys, &vals, n);
            assert_eq!(outs[j].out, want.out);
            assert_eq!(outs[j].weights, want.weights);
        }
    }

    #[test]
    fn lookat_kernel_zero_copy_path_matches_gathered_path() {
        let cache =
            filled_cache(pq_storage(4), &[(1, 33), (2, 64), (3, 100)]);
        let qs = queries(3, 11);
        let plan = plan_for(&cache, &qs, &[1, 2, 3], 2);
        let outs = LookatKernel.decode_batch(&plan).unwrap();
        let codecs = cache.codecs().unwrap();
        for (j, it) in plan.items.iter().enumerate() {
            let mut codes = Vec::new();
            let mut vals = Vec::new();
            let n = cache
                .gather_codes_into(it.seq, it.head, &mut codes)
                .unwrap();
            cache.gather_values_into(it.seq, it.head, &mut vals).unwrap();
            let lut =
                LookupTable::build(it.q, &codecs[it.head].codebook);
            let want = attention::lookat_attention_with_lut(
                &lut, &codes, &vals, n, DK);
            assert_eq!(outs[j].out, want.out, "item {j}");
            assert_eq!(outs[j].weights, want.weights, "item {j}");
        }
    }

    #[test]
    fn lookat_kv_kernel_matches_primitive() {
        // fully-compressed path: fused kernel output must be
        // bit-identical to lookat_kv_attention over gathered codes
        let cache = filled_cache_kv(
            pq_storage(4),
            pq_value_storage(4),
            &[(1, 33), (2, 64), (3, 100)],
        );
        let qs = queries(3, 17);
        let plan = plan_for(&cache, &qs, &[1, 2, 3], 2);
        let outs = LookatKernel.decode_batch(&plan).unwrap();
        let kcodecs = cache.codecs().unwrap();
        let vcodecs = cache.value_codecs().unwrap();
        for (j, it) in plan.items.iter().enumerate() {
            let mut kcodes = Vec::new();
            let mut vcodes = Vec::new();
            let n = cache
                .gather_codes_into(it.seq, it.head, &mut kcodes)
                .unwrap();
            cache
                .gather_value_codes_into(it.seq, it.head, &mut vcodes)
                .unwrap();
            let want = attention::lookat_kv_attention(
                it.q,
                &kcodes,
                &kcodecs[it.head],
                &vcodes,
                &vcodecs[it.head],
                n,
            );
            assert_eq!(outs[j].out, want.out, "item {j}");
            assert_eq!(outs[j].weights, want.weights, "item {j}");
        }
    }

    #[test]
    fn dense_kernels_with_pq_values_keep_key_side_weights() {
        // value coding must not change the attention distribution: the
        // fp16/int kernels over a PQ-value cache produce the same
        // weights as over an FP32-value cache with identical contents
        let seqs = [(1u64, 40usize), (2, 70)];
        let qs = queries(2, 19);
        let fp32 = filled_cache(KeyStorage::Fp16, &seqs);
        let vpq = filled_cache_kv(
            KeyStorage::Fp16, pq_value_storage(4), &seqs);
        let a = Fp16Kernel
            .decode_batch(&plan_for(&fp32, &qs, &[1, 2], 2))
            .unwrap();
        let b = Fp16Kernel
            .decode_batch(&plan_for(&vpq, &qs, &[1, 2], 2))
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.weights, y.weights);
        }
        let a = ScalarQuantKernel { bits: 8 }
            .decode_batch(&plan_for(&fp32, &qs, &[1, 2], 2))
            .unwrap();
        let b = ScalarQuantKernel { bits: 8 }
            .decode_batch(&plan_for(&vpq, &qs, &[1, 2], 2))
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.weights, y.weights);
        }
    }

    #[test]
    fn kernel_outputs_independent_of_thread_count() {
        let cache = filled_cache(pq_storage(2), &[(1, 50), (2, 50)]);
        let qs = queries(2, 13);
        let serial = LookatKernel
            .decode_batch(&plan_for(&cache, &qs, &[1, 2], 1))
            .unwrap();
        let parallel = LookatKernel
            .decode_batch(&plan_for(&cache, &qs, &[1, 2], 4))
            .unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.out, b.out);
            assert_eq!(a.weights, b.weights);
        }
    }

    /// Build a span plan over one sequence: every head carries `rows`
    /// query rows (the prefill-chunk shape).
    fn span_plan<'a>(
        cache: &'a KvCache,
        q_heads: &'a [Vec<f32>],
        seq: SeqId,
        rows: usize,
    ) -> DecodePlan<'a> {
        let items = (0..H)
            .map(|head| WorkItem {
                seq,
                head,
                q: &q_heads[head],
                rows,
                prefixes: None,
            })
            .collect();
        DecodePlan { cache, d_k: DK, threads: 2, timers: None, items }
    }

    #[test]
    fn span_rows_match_manual_prefix_attention() {
        // a rows=3 item's outputs must equal exact attention over each
        // row's causal prefix — the prefill-span contract every backend
        // inherits
        let n = 40usize;
        let rows = 3usize;
        let cache = filled_cache(KeyStorage::Fp16, &[(1, n)]);
        let mut rng = Pcg32::seed(23);
        // per head, a (rows × d_k) span of queries
        let q_heads: Vec<Vec<f32>> = (0..H)
            .map(|_| {
                (0..rows * DK).map(|_| rng.next_f32_std()).collect()
            })
            .collect();
        let plan = span_plan(&cache, &q_heads, 1, rows);
        let outs = Fp16Kernel.decode_batch(&plan).unwrap();
        assert_eq!(outs.len(), H * rows);
        for head in 0..H {
            let mut keys = Vec::new();
            let mut vals = Vec::new();
            cache.gather_keys_into(1, head, &mut keys).unwrap();
            cache.gather_values_into(1, head, &mut vals).unwrap();
            for r in 0..rows {
                let p = n - rows + r + 1;
                let q = &q_heads[head][r * DK..(r + 1) * DK];
                let want = attention::exact_attention(
                    q, &keys[..p * DK], &vals[..p * DK], p);
                let got = &outs[head * rows + r];
                assert_eq!(got.out, want.out, "head {head} row {r}");
                assert_eq!(got.weights, want.weights);
            }
        }
    }

    #[test]
    fn lookat_span_rows_match_prefix_scores() {
        let n = 70usize;
        let rows = 4usize;
        let cache = filled_cache(pq_storage(4), &[(1, n)]);
        let mut rng = Pcg32::seed(29);
        let q_heads: Vec<Vec<f32>> = (0..H)
            .map(|_| {
                (0..rows * DK).map(|_| rng.next_f32_std()).collect()
            })
            .collect();
        let plan = span_plan(&cache, &q_heads, 1, rows);
        let outs = LookatKernel.decode_batch(&plan).unwrap();
        let codecs = cache.codecs().unwrap();
        for head in 0..H {
            let mut codes = Vec::new();
            let mut vals = Vec::new();
            cache.gather_codes_into(1, head, &mut codes).unwrap();
            cache.gather_values_into(1, head, &mut vals).unwrap();
            for r in 0..rows {
                let p = n - rows + r + 1;
                let q = &q_heads[head][r * DK..(r + 1) * DK];
                let m = codecs[head].codebook.m;
                let want = attention::lookat_attention(
                    q, &codes[..p * m], &codecs[head],
                    &vals[..p * DK], p);
                let got = &outs[head * rows + r];
                assert_eq!(got.out, want.out, "head {head} row {r}");
                assert_eq!(got.weights, want.weights);
            }
        }
    }

    #[test]
    fn explicit_prefixes_override_derived_span_prefixes() {
        // the prune-aware contract: when the plan carries per-row
        // survivor counts, each row attends exactly that many cached
        // tokens — for every rust backend, key side and value side
        let n = 40usize;
        let rows = 3usize;
        let pfx = [5usize, 9, 40];
        let mut rng = Pcg32::seed(47);
        let q_heads: Vec<Vec<f32>> = (0..H)
            .map(|_| {
                (0..rows * DK).map(|_| rng.next_f32_std()).collect()
            })
            .collect();

        let cache = filled_cache(KeyStorage::Fp16, &[(1, n)]);
        let mut plan = span_plan(&cache, &q_heads, 1, rows);
        for it in plan.items.iter_mut() {
            it.prefixes = Some(&pfx);
        }
        let outs = Fp16Kernel.decode_batch(&plan).unwrap();
        for head in 0..H {
            let mut keys = Vec::new();
            let mut vals = Vec::new();
            cache.gather_keys_into(1, head, &mut keys).unwrap();
            cache.gather_values_into(1, head, &mut vals).unwrap();
            for (r, &p) in pfx.iter().enumerate() {
                let q = &q_heads[head][r * DK..(r + 1) * DK];
                let want = attention::exact_attention(
                    q, &keys[..p * DK], &vals[..p * DK], p);
                let got = &outs[head * rows + r];
                assert_eq!(got.out, want.out, "head {head} row {r}");
            }
        }

        let cache = filled_cache(pq_storage(4), &[(1, n)]);
        let mut plan = span_plan(&cache, &q_heads, 1, rows);
        for it in plan.items.iter_mut() {
            it.prefixes = Some(&pfx);
        }
        let outs = LookatKernel.decode_batch(&plan).unwrap();
        let codecs = cache.codecs().unwrap();
        for head in 0..H {
            let mut codes = Vec::new();
            let mut vals = Vec::new();
            cache.gather_codes_into(1, head, &mut codes).unwrap();
            cache.gather_values_into(1, head, &mut vals).unwrap();
            for (r, &p) in pfx.iter().enumerate() {
                let q = &q_heads[head][r * DK..(r + 1) * DK];
                let m = codecs[head].codebook.m;
                let want = attention::lookat_attention(
                    q, &codes[..p * m], &codecs[head],
                    &vals[..p * DK], p);
                let got = &outs[head * rows + r];
                assert_eq!(got.out, want.out, "head {head} row {r}");
            }
        }
    }

    #[test]
    fn unknown_seq_surfaces_as_error() {
        let cache = filled_cache(KeyStorage::Fp16, &[(1, 10)]);
        let qs = queries(1, 15);
        let plan = plan_for(&cache, &qs, &[99], 2);
        assert!(Fp16Kernel.decode_batch(&plan).is_err());
    }

    #[test]
    fn phase_timers_attribute_lut_scan_and_value_decode() {
        let cache = filled_cache_kv(
            pq_storage(4),
            pq_value_storage(4),
            &[(1, 50)],
        );
        let qs = queries(1, 33);
        let timers = PhaseTimers::new();
        let mut plan = plan_for(&cache, &qs, &[1], 1);
        plan.timers = Some(&timers);
        LookatKernel.decode_batch(&plan).unwrap();
        let t = timers.take();
        assert!(t.lut_build_s > 0.0, "lut_build not booked");
        assert!(t.scan_s > 0.0, "scan not booked");
        assert!(t.value_decode_s > 0.0, "value_decode not booked");
        // the kernel never touches the engine-side phases
        assert_eq!(t.qkv_s, 0.0);
        assert_eq!(t.mlp_s, 0.0);
    }

    #[test]
    fn timers_do_not_change_results() {
        let cache = filled_cache(pq_storage(4), &[(1, 64), (2, 33)]);
        let qs = queries(2, 35);
        let untimed = LookatKernel
            .decode_batch(&plan_for(&cache, &qs, &[1, 2], 2))
            .unwrap();
        let timers = PhaseTimers::new();
        let mut plan = plan_for(&cache, &qs, &[1, 2], 2);
        plan.timers = Some(&timers);
        let timed_outs = LookatKernel.decode_batch(&plan).unwrap();
        for (a, b) in untimed.iter().zip(&timed_outs) {
            assert_eq!(a.out, b.out);
            assert_eq!(a.weights, b.weights);
        }
    }

    #[test]
    fn steady_state_lookat_tick_reuses_arena_buffers() {
        // after warm-up ticks, repeated identical plans satisfy their
        // scratch leases from the pool — the zero-allocation contract
        // of the arena-backed hot path. The pool is process-wide and
        // other tests take from it concurrently (which can force
        // fresh allocations that are not this kernel's fault), so the
        // deterministic assertion is relative: the steady-state window
        // must recycle for the majority of its takes. The exact
        // zero-allocation property is pinned deterministically on a
        // private pool in util::threadpool's
        // scratch_pool_steady_state_allocates_nothing.
        let cache = filled_cache_kv(
            pq_storage(4),
            pq_value_storage(4),
            &[(1, 70), (2, 40)],
        );
        let qs = queries(2, 41);
        let mut run_tick = || {
            let plan = plan_for(&cache, &qs, &[1, 2], 1);
            let outs = LookatKernel.decode_batch(&plan).unwrap();
            for o in outs {
                scratch().put_f32(o.out);
                scratch().put_f32(o.weights);
            }
        };
        for _ in 0..3 {
            run_tick(); // warm-up: populate the pool
        }
        let (takes_before, fresh_before) = scratch().stats();
        for _ in 0..10 {
            run_tick();
        }
        let (takes_after, fresh_after) = scratch().stats();
        let takes = takes_after - takes_before;
        let fresh = fresh_after - fresh_before;
        assert!(takes > 0, "ticks must lease scratch from the pool");
        // in isolation fresh == 0; concurrent tests can transiently
        // drain the shared pool, so only require that recycling
        // demonstrably happened — never all-fresh
        assert!(
            fresh < takes,
            "steady-state ticks allocated {fresh} of {takes} leases"
        );
    }
}
