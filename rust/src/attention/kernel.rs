//! Batched decode kernels: the [`AttentionKernel`] trait and its five
//! backends (fp16, lookat, scalar-quant, pjrt-fp16, pjrt-lookat).
//!
//! The engine builds one [`DecodePlan`] per layer per batcher tick —
//! every (seq, head) of the drained batch at once — and hands it to the
//! kernel. The pure-rust kernels fan the independent items out on
//! `util::threadpool`; the PJRT kernels own the runtime client (whose
//! handles are not `Send`) and walk the plan's per-sequence groups
//! serially, packing one padded artifact call per sequence exactly as
//! the old per-seq path did.
//!
//! The LOOKAT kernel is the paper's bandwidth story end-to-end: it
//! builds the LUT per (seq, head) query, scans the PQ codes *in place*
//! over the cache's head-major blocks ([`LookupTable::scores_blocks`])
//! and accumulates α·V straight from the same views — zero per-step
//! key-code copies.
//!
//! Every pure-rust kernel is additionally *value-storage aware*: when
//! the plan's cache stores PQ-coded values
//! ([`crate::kvcache::ValueStorage::Pq`]), the attention tail switches
//! to the fused blocked weighted decode
//! ([`finish_attention_kv_blocks`]) — post-softmax weights are
//! scatter-accumulated into per-subspace tables while the value-code
//! blocks stream, so values are never dequantized per token either.
//! LOOKAT keys × PQ values is the paper's fully-compressed "lookat-kv"
//! combination with zero per-step copies on *both* cache sides.

use anyhow::{bail, Context};

use super::{
    finish_attention_blocks, finish_attention_kv_blocks, AttnOutput,
};
use crate::attention;
use crate::kvcache::{CacheError, KvCache, SeqId};
use crate::pq::LookupTable;
use crate::runtime::{InputArg, Runtime};
use crate::util::threadpool::parallel_try_map;

/// One (seq, head) attention task of a decode tick.
pub struct WorkItem<'a> {
    pub seq: SeqId,
    pub head: usize,
    /// this head's query, (d_k)
    pub q: &'a [f32],
}

/// All attention work of one layer for one decode tick.
///
/// Items are seq-major: the engine emits every head of a sequence
/// consecutively, heads ascending — the PJRT kernels rely on this to
/// regroup items into one padded artifact call per sequence.
pub struct DecodePlan<'a> {
    /// the layer's cache; every item resolves against it
    pub cache: &'a KvCache,
    pub d_k: usize,
    /// worker threads to fan items out on (1 = serial)
    pub threads: usize,
    pub items: Vec<WorkItem<'a>>,
}

/// A batched attention backend: scores and attends every (seq, head)
/// item of a [`DecodePlan`], returning outputs in item order.
pub trait AttentionKernel {
    /// Kernel name (diagnostics / reports).
    fn name(&self) -> &'static str;

    /// Run the whole plan. Outputs align with `plan.items`.
    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>;
}

std::thread_local! {
    /// Per-thread gather scratch (keys, values) for the dense kernels:
    /// two allocations per fan-out worker instead of two per (seq,
    /// head) item. Fan-out now runs on `util::threadpool`'s persistent
    /// process-wide pool, so workers — and this scratch — survive
    /// across decode ticks; the serial (threads = 1) path carries its
    /// capacity on the engine thread the same way.
    static GATHER_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<f32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

/// Gather one item's keys and values into the thread's scratch and
/// score with `f` (FP32-value caches only).
fn with_gathered<F>(
    plan: &DecodePlan<'_>,
    it: &WorkItem<'_>,
    f: F,
) -> Result<AttnOutput, CacheError>
where
    F: FnOnce(&[f32], &[f32], usize) -> AttnOutput,
{
    GATHER_SCRATCH.with(|s| {
        let (keys, vals) = &mut *s.borrow_mut();
        let n = plan.cache.gather_keys_into(it.seq, it.head, keys)?;
        plan.cache.gather_values_into(it.seq, it.head, vals)?;
        Ok(f(keys, vals, n))
    })
}

/// Raw (unscaled) dense scores of one query against gathered keys.
fn dense_scores(q: &[f32], keys: &[f32], n: usize) -> Vec<f32> {
    let d_k = q.len();
    (0..n)
        .map(|l| crate::tensor::dot(q, &keys[l * d_k..(l + 1) * d_k]))
        .collect()
}

/// Shared attention tail for one plan item given its raw scores:
/// block-resident α·V over raw values, or the fused blocked weighted
/// decode when the cache stores PQ-coded values.
fn finish_item(
    plan: &DecodePlan<'_>,
    it: &WorkItem<'_>,
    scores: Vec<f32>,
) -> Result<AttnOutput, CacheError> {
    match plan.cache.value_codecs() {
        None => Ok(finish_attention_blocks(
            scores,
            plan.cache.blocks(it.seq, it.head)?,
            plan.d_k,
        )),
        Some(vcodecs) => Ok(finish_attention_kv_blocks(
            scores,
            plan.cache.blocks(it.seq, it.head)?,
            &vcodecs[it.head],
            plan.d_k,
        )),
    }
}

/// Exact attention over FP16-stored keys (gathers the paged cache into
/// contiguous scratch per item — dense scoring needs one flat tensor).
/// With PQ-coded values, only the keys are gathered; the value side
/// runs the fused blocked weighted decode.
pub struct Fp16Kernel;

impl AttentionKernel for Fp16Kernel {
    fn name(&self) -> &'static str {
        "fp16"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let pq_values = plan.cache.value_codecs().is_some();
        parallel_try_map(plan.items.len(), plan.threads, |i| {
            let it = &plan.items[i];
            if pq_values {
                let scores = GATHER_SCRATCH.with(|s| {
                    let (keys, _) = &mut *s.borrow_mut();
                    let n =
                        plan.cache.gather_keys_into(it.seq, it.head, keys)?;
                    Ok::<_, CacheError>(dense_scores(it.q, keys, n))
                })?;
                finish_item(plan, it, scores)
            } else {
                with_gathered(plan, it, |keys, vals, n| {
                    attention::exact_attention(it.q, keys, vals, n)
                })
            }
        })
        .map_err(|e: CacheError| anyhow::anyhow!("fp16 decode: {e}"))
    }
}

/// INT4/INT8 round-trip baseline (gathers, dequantizes, then scores —
/// the bandwidth-bound path the paper compares against). With PQ-coded
/// values this is the "int-key × pq-value" combination: round-tripped
/// key scores feed the fused blocked weighted decode.
pub struct ScalarQuantKernel {
    pub bits: u8,
}

impl AttentionKernel for ScalarQuantKernel {
    fn name(&self) -> &'static str {
        "scalar-quant"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let bits = self.bits;
        let pq_values = plan.cache.value_codecs().is_some();
        parallel_try_map(plan.items.len(), plan.threads, |i| {
            let it = &plan.items[i];
            if pq_values {
                let scores = GATHER_SCRATCH.with(|s| {
                    let (keys, _) = &mut *s.borrow_mut();
                    let n =
                        plan.cache.gather_keys_into(it.seq, it.head, keys)?;
                    let deq = crate::quant::quant_roundtrip(keys, bits);
                    Ok::<_, CacheError>(dense_scores(it.q, &deq, n))
                })?;
                finish_item(plan, it, scores)
            } else {
                with_gathered(plan, it, |keys, vals, n| {
                    attention::scalar_quant_attention(
                        it.q, keys, vals, n, bits)
                })
            }
        })
        .map_err(|e: CacheError| anyhow::anyhow!("int{bits} decode: {e}"))
    }
}

/// LOOKAT ADC over the block-resident PQ codes: LUT build per item,
/// then scores and α·V accumulated straight from the cache's
/// [`crate::kvcache::BlockView`]s — no gather copies at all. With
/// PQ-coded values this is the paper's fully-compressed **lookat-kv**
/// path: both the key-code scan and the value weighted decode are
/// block-resident, zero per-step copies on either cache side.
pub struct LookatKernel;

impl AttentionKernel for LookatKernel {
    fn name(&self) -> &'static str {
        "lookat"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let codecs = plan
            .cache
            .codecs()
            .context("lookat kernel needs a PQ cache")?
            .clone();
        parallel_try_map(plan.items.len(), plan.threads, |i| {
            let it = &plan.items[i];
            let lut = LookupTable::build(it.q, &codecs[it.head].codebook);
            let n = plan.cache.seq_len(it.seq)?;
            let mut scores = Vec::with_capacity(n);
            lut.scores_blocks(
                plan.cache.blocks(it.seq, it.head)?.map(|b| b.codes),
                &mut scores,
            );
            finish_item(plan, it, scores)
        })
        .map_err(|e: CacheError| anyhow::anyhow!("lookat decode: {e}"))
    }
}

/// Smallest artifact length that fits `n` cached tokens.
fn pjrt_len_for(lens: &[usize], n: usize) -> anyhow::Result<usize> {
    lens.iter().copied().find(|&l| l >= n).with_context(|| {
        format!(
            "cache length {n} exceeds largest artifact L={:?}",
            lens.last()
        )
    })
}

/// Split a seq-major plan into per-sequence groups of `h` items and
/// check the ordering contract the engine promises.
fn seq_groups<'p, 'a>(
    plan: &'p DecodePlan<'a>,
) -> anyhow::Result<std::slice::Chunks<'p, WorkItem<'a>>> {
    let h = plan.cache.h;
    if plan.items.len() % h != 0 {
        bail!(
            "DecodePlan has {} items, not a multiple of H={h}",
            plan.items.len()
        );
    }
    for group in plan.items.chunks(h) {
        for (j, it) in group.iter().enumerate() {
            if it.head != j || it.seq != group[0].seq {
                bail!("DecodePlan items must be seq-major with ascending \
                       heads");
            }
        }
    }
    Ok(plan.items.chunks(h))
}

/// Split one full-width context row (H · d_k) into per-head outputs.
/// PJRT artifacts return no attention distribution, so `weights` is
/// empty — the serving loop only consumes `out`.
fn split_heads(full: &[f32], h: usize, d_k: usize) -> Vec<AttnOutput> {
    (0..h)
        .map(|head| AttnOutput {
            out: full[head * d_k..(head + 1) * d_k].to_vec(),
            weights: Vec::new(),
        })
        .collect()
}

/// FP16 attention through the AOT artifacts on the PJRT client. The
/// client's handles are not `Send`, so sequences run serially on the
/// engine thread; each sequence is one padded artifact execution.
pub struct PjrtFp16Kernel {
    runtime: Runtime,
    lens: Vec<usize>,
    scratch_keys: Vec<f32>,
    scratch_vals: Vec<f32>,
}

impl PjrtFp16Kernel {
    pub fn new(runtime: Runtime, lens: Vec<usize>) -> Self {
        Self {
            runtime,
            lens,
            scratch_keys: Vec::new(),
            scratch_vals: Vec::new(),
        }
    }

    fn attend_seq(
        &mut self,
        cache: &KvCache,
        seq: SeqId,
        q: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        let (h, d_k) = (cache.h, cache.d_k);
        let n = cache.seq_len(seq).map_err(|e| anyhow::anyhow!("{e}"))?;
        let l = pjrt_len_for(&self.lens, n)?;
        // pack (H, L, d_k) padded keys/values + (L,) mask
        let mut k = vec![0.0f32; h * l * d_k];
        let mut v = vec![0.0f32; h * l * d_k];
        let mut mask = vec![0.0f32; l];
        mask[..n].fill(1.0);
        for head in 0..h {
            cache
                .gather_keys_into(seq, head, &mut self.scratch_keys)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            cache
                .gather_values_into(seq, head, &mut self.scratch_vals)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            k[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_keys);
            v[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_vals);
        }
        let name = format!("attn_fp16_L{l}");
        let outs = self.runtime.execute(
            &name,
            &[
                InputArg::F32(q),
                InputArg::F32(&k),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )?;
        Ok(outs.into_iter().next().unwrap())
    }
}

impl AttentionKernel for PjrtFp16Kernel {
    fn name(&self) -> &'static str {
        "pjrt-fp16"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let (h, d_k) = (plan.cache.h, plan.d_k);
        let groups: Vec<(SeqId, Vec<f32>)> = seq_groups(plan)?
            .map(|group| {
                let mut q = vec![0.0f32; h * d_k];
                for it in group {
                    q[it.head * d_k..(it.head + 1) * d_k]
                        .copy_from_slice(it.q);
                }
                (group[0].seq, q)
            })
            .collect();
        let mut outs = Vec::with_capacity(plan.items.len());
        for (seq, q) in groups {
            let full = self.attend_seq(plan.cache, seq, &q)?;
            outs.extend(split_heads(&full, h, d_k));
        }
        Ok(outs)
    }
}

/// LOOKAT attention through the AOT artifacts on the PJRT client.
pub struct PjrtLookatKernel {
    runtime: Runtime,
    lens: Vec<usize>,
    m: usize,
    scratch_codes: Vec<u8>,
    scratch_vals: Vec<f32>,
}

impl PjrtLookatKernel {
    pub fn new(runtime: Runtime, lens: Vec<usize>, m: usize) -> Self {
        Self {
            runtime,
            lens,
            m,
            scratch_codes: Vec::new(),
            scratch_vals: Vec::new(),
        }
    }

    fn attend_seq(
        &mut self,
        cache: &KvCache,
        seq: SeqId,
        q: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        let (h, d_k) = (cache.h, cache.d_k);
        let m = self.m;
        let codecs = cache
            .codecs()
            .context("pjrt-lookat kernel needs a PQ cache")?
            .clone();
        let n = cache.seq_len(seq).map_err(|e| anyhow::anyhow!("{e}"))?;
        let l = pjrt_len_for(&self.lens, n)?;
        let kk = codecs[0].codebook.k;
        let d_sub = d_k / m;
        let mut codes = vec![0i32; h * l * m];
        let mut cbs = vec![0.0f32; h * m * kk * d_sub];
        let mut v = vec![0.0f32; h * l * d_k];
        let mut mask = vec![0.0f32; l];
        mask[..n].fill(1.0);
        for head in 0..h {
            cache
                .gather_codes_into(seq, head, &mut self.scratch_codes)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            cache
                .gather_values_into(seq, head, &mut self.scratch_vals)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            for (i, &c) in self.scratch_codes.iter().enumerate() {
                codes[head * l * m + i] = c as i32;
            }
            v[head * l * d_k..head * l * d_k + n * d_k]
                .copy_from_slice(&self.scratch_vals);
            let flat = codecs[head].codebook.to_flat();
            cbs[head * m * kk * d_sub..(head + 1) * m * kk * d_sub]
                .copy_from_slice(&flat);
        }
        let name = format!("attn_lookat_m{m}_L{l}");
        let outs = self.runtime.execute(
            &name,
            &[
                InputArg::F32(q),
                InputArg::I32(&codes),
                InputArg::F32(&cbs),
                InputArg::F32(&v),
                InputArg::F32(&mask),
            ],
        )?;
        Ok(outs.into_iter().next().unwrap())
    }
}

impl AttentionKernel for PjrtLookatKernel {
    fn name(&self) -> &'static str {
        "pjrt-lookat"
    }

    fn decode_batch(&mut self, plan: &DecodePlan<'_>)
        -> anyhow::Result<Vec<AttnOutput>>
    {
        let (h, d_k) = (plan.cache.h, plan.d_k);
        let groups: Vec<(SeqId, Vec<f32>)> = seq_groups(plan)?
            .map(|group| {
                let mut q = vec![0.0f32; h * d_k];
                for it in group {
                    q[it.head * d_k..(it.head + 1) * d_k]
                        .copy_from_slice(it.q);
                }
                (group[0].seq, q)
            })
            .collect();
        let mut outs = Vec::with_capacity(plan.items.len());
        for (seq, q) in groups {
            let full = self.attend_seq(plan.cache, seq, &q)?;
            outs.extend(split_heads(&full, h, d_k));
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KeyStorage, KvCache, ValueStorage};
    use crate::pq::{PqCodec, TrainOpts};
    use crate::util::rng::Pcg32;

    const H: usize = 2;
    const DK: usize = 16;

    fn filled_cache_kv(
        storage: KeyStorage,
        values: ValueStorage,
        seqs: &[(SeqId, usize)],
    ) -> KvCache {
        let mut c = KvCache::new(H, DK, 64, storage, values);
        for &(id, n) in seqs {
            c.create_seq(id).unwrap();
            let mut rng = Pcg32::seed(0xC0 + id);
            for _ in 0..n {
                let k: Vec<f32> =
                    (0..H * DK).map(|_| rng.next_f32_std()).collect();
                let v: Vec<f32> =
                    (0..H * DK).map(|_| rng.next_f32_std()).collect();
                c.append(id, &k, &v).unwrap();
            }
        }
        c
    }

    fn filled_cache(storage: KeyStorage, seqs: &[(SeqId, usize)])
        -> KvCache
    {
        filled_cache_kv(storage, ValueStorage::Fp32, seqs)
    }

    fn trained_codecs(m: usize, seed: u64) -> Vec<PqCodec> {
        let mut rng = Pcg32::seed(seed);
        let calib: Vec<f32> =
            (0..256 * DK).map(|_| rng.next_f32_std()).collect();
        (0..H)
            .map(|_| {
                PqCodec::train(&calib, DK, m, 16, &TrainOpts::default())
            })
            .collect()
    }

    fn pq_storage(m: usize) -> KeyStorage {
        KeyStorage::pq(trained_codecs(m, 77)).unwrap()
    }

    fn pq_value_storage(m: usize) -> ValueStorage {
        ValueStorage::pq(trained_codecs(m, 78)).unwrap()
    }

    fn plan_for<'a>(
        cache: &'a KvCache,
        qs: &'a [Vec<f32>],
        seqs: &[SeqId],
        threads: usize,
    ) -> DecodePlan<'a> {
        let mut items = Vec::new();
        for (i, &seq) in seqs.iter().enumerate() {
            for head in 0..H {
                items.push(WorkItem {
                    seq,
                    head,
                    q: &qs[i][head * DK..(head + 1) * DK],
                });
            }
        }
        DecodePlan { cache, d_k: DK, threads, items }
    }

    fn queries(n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seed(seed);
        (0..n)
            .map(|_| (0..H * DK).map(|_| rng.next_f32_std()).collect())
            .collect()
    }

    #[test]
    fn fp16_kernel_matches_direct_attention() {
        let cache =
            filled_cache(KeyStorage::Fp16, &[(1, 40), (2, 70), (3, 5)]);
        let qs = queries(3, 9);
        let plan = plan_for(&cache, &qs, &[1, 2, 3], 2);
        let outs = Fp16Kernel.decode_batch(&plan).unwrap();
        assert_eq!(outs.len(), 6);
        for (j, it) in plan.items.iter().enumerate() {
            let mut keys = Vec::new();
            let mut vals = Vec::new();
            let n = cache
                .gather_keys_into(it.seq, it.head, &mut keys)
                .unwrap();
            cache.gather_values_into(it.seq, it.head, &mut vals).unwrap();
            let want = attention::exact_attention(it.q, &keys, &vals, n);
            assert_eq!(outs[j].out, want.out);
            assert_eq!(outs[j].weights, want.weights);
        }
    }

    #[test]
    fn lookat_kernel_zero_copy_path_matches_gathered_path() {
        let cache =
            filled_cache(pq_storage(4), &[(1, 33), (2, 64), (3, 100)]);
        let qs = queries(3, 11);
        let plan = plan_for(&cache, &qs, &[1, 2, 3], 2);
        let outs = LookatKernel.decode_batch(&plan).unwrap();
        let codecs = cache.codecs().unwrap();
        for (j, it) in plan.items.iter().enumerate() {
            let mut codes = Vec::new();
            let mut vals = Vec::new();
            let n = cache
                .gather_codes_into(it.seq, it.head, &mut codes)
                .unwrap();
            cache.gather_values_into(it.seq, it.head, &mut vals).unwrap();
            let lut =
                LookupTable::build(it.q, &codecs[it.head].codebook);
            let want = attention::lookat_attention_with_lut(
                &lut, &codes, &vals, n, DK);
            assert_eq!(outs[j].out, want.out, "item {j}");
            assert_eq!(outs[j].weights, want.weights, "item {j}");
        }
    }

    #[test]
    fn lookat_kv_kernel_matches_primitive() {
        // fully-compressed path: fused kernel output must be
        // bit-identical to lookat_kv_attention over gathered codes
        let cache = filled_cache_kv(
            pq_storage(4),
            pq_value_storage(4),
            &[(1, 33), (2, 64), (3, 100)],
        );
        let qs = queries(3, 17);
        let plan = plan_for(&cache, &qs, &[1, 2, 3], 2);
        let outs = LookatKernel.decode_batch(&plan).unwrap();
        let kcodecs = cache.codecs().unwrap();
        let vcodecs = cache.value_codecs().unwrap();
        for (j, it) in plan.items.iter().enumerate() {
            let mut kcodes = Vec::new();
            let mut vcodes = Vec::new();
            let n = cache
                .gather_codes_into(it.seq, it.head, &mut kcodes)
                .unwrap();
            cache
                .gather_value_codes_into(it.seq, it.head, &mut vcodes)
                .unwrap();
            let want = attention::lookat_kv_attention(
                it.q,
                &kcodes,
                &kcodecs[it.head],
                &vcodes,
                &vcodecs[it.head],
                n,
            );
            assert_eq!(outs[j].out, want.out, "item {j}");
            assert_eq!(outs[j].weights, want.weights, "item {j}");
        }
    }

    #[test]
    fn dense_kernels_with_pq_values_keep_key_side_weights() {
        // value coding must not change the attention distribution: the
        // fp16/int kernels over a PQ-value cache produce the same
        // weights as over an FP32-value cache with identical contents
        let seqs = [(1u64, 40usize), (2, 70)];
        let qs = queries(2, 19);
        let fp32 = filled_cache(KeyStorage::Fp16, &seqs);
        let vpq = filled_cache_kv(
            KeyStorage::Fp16, pq_value_storage(4), &seqs);
        let a = Fp16Kernel
            .decode_batch(&plan_for(&fp32, &qs, &[1, 2], 2))
            .unwrap();
        let b = Fp16Kernel
            .decode_batch(&plan_for(&vpq, &qs, &[1, 2], 2))
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.weights, y.weights);
        }
        let a = ScalarQuantKernel { bits: 8 }
            .decode_batch(&plan_for(&fp32, &qs, &[1, 2], 2))
            .unwrap();
        let b = ScalarQuantKernel { bits: 8 }
            .decode_batch(&plan_for(&vpq, &qs, &[1, 2], 2))
            .unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.weights, y.weights);
        }
    }

    #[test]
    fn kernel_outputs_independent_of_thread_count() {
        let cache = filled_cache(pq_storage(2), &[(1, 50), (2, 50)]);
        let qs = queries(2, 13);
        let serial = LookatKernel
            .decode_batch(&plan_for(&cache, &qs, &[1, 2], 1))
            .unwrap();
        let parallel = LookatKernel
            .decode_batch(&plan_for(&cache, &qs, &[1, 2], 4))
            .unwrap();
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.out, b.out);
            assert_eq!(a.weights, b.weights);
        }
    }

    #[test]
    fn unknown_seq_surfaces_as_error() {
        let cache = filled_cache(KeyStorage::Fp16, &[(1, 10)]);
        let qs = queries(1, 15);
        let plan = plan_for(&cache, &qs, &[99], 2);
        assert!(Fp16Kernel.decode_batch(&plan).is_err());
    }
}
