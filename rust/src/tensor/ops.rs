//! Elementwise / normalization ops for the pure-rust transformer.

use super::Tensor2;

/// Numerically-stable in-place softmax over a single slice.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let mx = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// Row-wise softmax of a matrix (attention weights over each query row).
pub fn softmax_rows(t: &mut Tensor2) {
    for r in 0..t.rows {
        softmax_inplace(t.row_mut(r));
    }
}

/// LayerNorm over the last axis: (x - mean)/sqrt(var + eps) * g + b.
pub fn layernorm(x: &[f32], g: &[f32], b: &[f32], eps: f32) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    layernorm_into(x, g, b, eps, &mut out);
    out
}

/// [`layernorm`] into a caller buffer (the engine's batched GEMM
/// stages normalize rows into pooled staging tensors without per-row
/// allocations). Bit-identical to [`layernorm`] — same reduction and
/// elementwise order.
pub fn layernorm_into(
    x: &[f32],
    g: &[f32],
    b: &[f32],
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(x.len(), g.len());
    assert_eq!(x.len(), b.len());
    assert_eq!(x.len(), out.len());
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + eps).sqrt();
    for (o, (v, (gi, bi))) in
        out.iter_mut().zip(x.iter().zip(g.iter().zip(b.iter())))
    {
        *o = (v - mean) * inv * gi + bi;
    }
}

/// GPT-2's tanh-approximation GELU, in place.
/// Must match python/compile/model.py::gelu bit-for-bit in formula.
pub fn gelu_inplace(x: &mut [f32]) {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    for v in x.iter_mut() {
        let u = *v;
        *v = 0.5 * u * (1.0 + (C * (u + 0.044715 * u * u * u)).tanh());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1000.0, 1001.0, 999.0]; // would overflow naive exp
        softmax_inplace(&mut x);
        let sum: f32 = x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(x[1] > x[0] && x[0] > x[2]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn softmax_uniform_for_equal_inputs() {
        let mut x = vec![3.0; 5];
        softmax_inplace(&mut x);
        for v in &x {
            assert!((v - 0.2).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_empty_is_noop() {
        let mut x: Vec<f32> = vec![];
        softmax_inplace(&mut x);
        assert!(x.is_empty());
    }

    #[test]
    fn softmax_rows_normalizes_each_row() {
        let mut t = Tensor2::from_vec(2, 3, vec![1., 2., 3., 0., 0., 10.]);
        softmax_rows(&mut t);
        for r in 0..2 {
            let s: f32 = t.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(t.at(1, 2) > 0.99);
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let x: Vec<f32> = (0..64).map(|i| i as f32 * 0.5 - 7.0).collect();
        let g = vec![1.0; 64];
        let b = vec![0.0; 64];
        let y = layernorm(&x, &g, &b, 1e-5);
        let mean: f32 = y.iter().sum::<f32>() / 64.0;
        let var: f32 = y.iter().map(|v| v * v).sum::<f32>() / 64.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn layernorm_applies_gain_and_bias() {
        let x = vec![1.0, -1.0];
        let y = layernorm(&x, &[2.0, 2.0], &[10.0, 10.0], 1e-5);
        assert!((y[0] - 12.0).abs() < 1e-2);
        assert!((y[1] - 8.0).abs() < 1e-2);
    }

    #[test]
    fn gelu_reference_points() {
        // mirror of python/tests/test_model.py::test_gelu_reference_points
        let mut x = vec![0.0, 3.0, -3.0];
        gelu_inplace(&mut x);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 2.9964).abs() < 1e-3);
        assert!((x[2] + 0.0036).abs() < 1e-3);
    }

    #[test]
    fn gelu_monotone_on_positive_axis() {
        let mut x: Vec<f32> = (0..100).map(|i| i as f32 * 0.1).collect();
        let orig = x.clone();
        gelu_inplace(&mut x);
        for i in 1..100 {
            assert!(x[i] >= x[i - 1]);
            assert!(x[i] <= orig[i]); // gelu(x) <= x for x >= 0
        }
    }
}
