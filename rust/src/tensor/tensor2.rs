//! Dense row-major 2-D f32 tensor with cache-blocked matmul.

use crate::util::rng::Pcg32;

/// Row-major (rows × cols) f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// N(0, sigma^2) initialization.
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Pcg32)
        -> Self
    {
        let data = (0..rows * cols)
            .map(|_| rng.next_normal(0.0, sigma))
            .collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Tensor2 {
        let mut t = Tensor2::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// self (R×K) @ other (K×C) -> (R×C), cache-blocked i-k-j loop order.
    ///
    /// The k-j inner loops stream `other` rows sequentially and accumulate
    /// into the output row, which LLVM vectorizes; blocking keeps the
    /// working set in L1/L2. This is the pure-rust model's hot matmul.
    pub fn matmul(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (r_n, k_n, c_n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor2::zeros(r_n, c_n);
        const KB: usize = 64; // k-block: other-rows chunk resident in L1
        for k0 in (0..k_n).step_by(KB) {
            let k1 = (k0 + KB).min(k_n);
            for r in 0..r_n {
                let arow = self.row(r);
                let orow = out.row_mut(r);
                for k in k0..k1 {
                    let a = arow[k];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[k * c_n..(k + 1) * c_n];
                    super::axpy(orow, a, brow);
                }
            }
        }
        out
    }

    /// Matrix–vector product: self (R×C) @ x (C) -> (R).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| super::dot(self.row(r), x)).collect()
    }

    /// Vector–matrix product: x (R) @ self (R×C) -> (C).
    /// Streams rows (sequential access) instead of striding columns.
    pub fn vecmat(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0f32; self.cols];
        for (r, &xv) in x.iter().enumerate() {
            if xv != 0.0 {
                super::axpy(&mut out, xv, self.row(r));
            }
        }
        out
    }

    /// Add a row-broadcast bias in place.
    pub fn add_bias(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            for (v, b) in self.row_mut(r).iter_mut().zip(bias) {
                *v += *b;
            }
        }
    }

    /// Frobenius norm (tests / debugging).
    pub fn fro_norm(&self) -> f32 {
        super::dot(&self.data, &self.data).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Tensor2, b: &Tensor2) -> Tensor2 {
        let mut out = Tensor2::zeros(a.rows, b.cols);
        for r in 0..a.rows {
            for c in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(r, k) * b.at(k, c);
                }
                out.set(r, c, s);
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg32::seed(1);
        for (r, k, c) in [(3, 4, 5), (17, 33, 9), (64, 128, 64), (1, 70, 1)] {
            let a = Tensor2::randn(r, k, 1.0, &mut rng);
            let b = Tensor2::randn(k, c, 1.0, &mut rng);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            for i in 0..got.data.len() {
                assert!(
                    (got.data[i] - want.data[i]).abs() < 1e-3,
                    "mismatch at {i}: {} vs {}",
                    got.data[i],
                    want.data[i]
                );
            }
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg32::seed(2);
        let a = Tensor2::randn(8, 8, 1.0, &mut rng);
        let mut eye = Tensor2::zeros(8, 8);
        for i in 0..8 {
            eye.set(i, i, 1.0);
        }
        let out = a.matmul(&eye);
        for i in 0..64 {
            assert!((out.data[i] - a.data[i]).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_mismatch_panics() {
        let a = Tensor2::zeros(2, 3);
        let b = Tensor2::zeros(4, 2);
        let _ = a.matmul(&b);
    }

    #[test]
    fn matvec_and_vecmat_consistent_with_matmul() {
        let mut rng = Pcg32::seed(3);
        let a = Tensor2::randn(6, 9, 1.0, &mut rng);
        let x: Vec<f32> = (0..9).map(|i| i as f32 * 0.3 - 1.0).collect();
        let xcol = Tensor2::from_vec(9, 1, x.clone());
        let want = a.matmul(&xcol);
        let got = a.matvec(&x);
        for i in 0..6 {
            assert!((got[i] - want.data[i]).abs() < 1e-4);
        }

        let y: Vec<f32> = (0..6).map(|i| (i as f32).cos()).collect();
        let yrow = Tensor2::from_vec(1, 6, y.clone());
        let want2 = yrow.matmul(&a);
        let got2 = a.vecmat(&y);
        for i in 0..9 {
            assert!((got2[i] - want2.data[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::seed(4);
        let a = Tensor2::randn(5, 7, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().at(3, 2), a.at(2, 3));
    }

    #[test]
    fn add_bias_broadcasts() {
        let mut a = Tensor2::zeros(2, 3);
        a.add_bias(&[1.0, 2.0, 3.0]);
        assert_eq!(a.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(a.row(1), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Pcg32::seed(5);
        let t = Tensor2::randn(100, 100, 2.0, &mut rng);
        let mean: f32 = t.data.iter().sum::<f32>() / 10_000.0;
        let var: f32 =
            t.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
                / 10_000.0;
        assert!(mean.abs() < 0.1);
        assert!((var - 4.0).abs() < 0.3);
    }
}
