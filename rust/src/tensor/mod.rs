//! Minimal f32 tensor substrate powering the pure-rust model and the
//! experiment harness.
//!
//! Row-major dense tensors with the handful of ops a GPT-2-style forward
//! needs: blocked matmul, bias add, layernorm, GELU, softmax, transpose.
//! The matmul is cache-blocked and written so LLVM auto-vectorizes the
//! inner kernel (see `matmul` and rust/benches/micro_hotpaths.rs).

mod ops;
mod tensor2;

pub use ops::{gelu_inplace, layernorm, softmax_inplace, softmax_rows};
pub use tensor2::Tensor2;

/// Dot product of two equal-length slices (unrolled for autovectorization).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        // 8-wide partial sums: LLVM lowers this to SIMD fma on x86-64.
        for j in 0..8 {
            acc[j] += a[i + j] * b[i + j];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Squared L2 distance between two slices.
#[inline]
pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..100).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..100).map(|i| (i as f32 * 0.05).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn dot_handles_short_and_unaligned() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        let a = [1.0; 13];
        let b = [2.0; 13];
        assert_eq!(dot(&a, &b), 26.0);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 0.5, &[4.0, 8.0]);
        assert_eq!(y, vec![3.0, 6.0]);
    }

    #[test]
    fn dist2_basic() {
        assert_eq!(dist2(&[0.0, 3.0], &[4.0, 0.0]), 25.0);
        assert_eq!(dist2(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn norm2_basic() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
