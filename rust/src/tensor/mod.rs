//! Minimal f32 tensor substrate powering the pure-rust model and the
//! experiment harness.
//!
//! Row-major dense tensors with the handful of ops a GPT-2-style forward
//! needs: blocked matmul, bias add, layernorm, GELU, softmax, transpose.
//! The matmul is cache-blocked and written so LLVM auto-vectorizes the
//! inner kernel (see `matmul` and rust/benches/micro_hotpaths.rs).

mod ops;
mod tensor2;

pub use ops::{
    gelu_inplace, layernorm, layernorm_into, softmax_inplace, softmax_rows,
};
pub use tensor2::Tensor2;

/// Dot product of two equal-length slices (unrolled for autovectorization).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        // 8-wide partial sums: LLVM lowers this to SIMD fma on x86-64.
        for j in 0..8 {
            acc[j] += a[i + j] * b[i + j];
        }
    }
    let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

/// Row-batched vector–matrix product: row `r` of `x` (rows × k_n,
/// row-major) times `w` (k_n × c_n) into row `r` of `out`.
///
/// Per output element the accumulation over `k` runs ascending with
/// zero coefficients skipped — the *identical* float-op sequence as
/// [`Tensor2::vecmat`] on that row, so a batch through this kernel is
/// bit-identical to per-row `vecmat` calls. The difference is purely
/// locality: `w` is streamed in k-blocks shared by every row, so at
/// decode batch width B the weight matrix crosses memory once per
/// block instead of B times — this is what turns the engine's QKV/MLP
/// stages from weight-bandwidth-bound to compute-bound (the decode
/// hot-path overhaul's GEMM batching).
pub fn matmul_rows_into(x: &[f32], w: &Tensor2, out: &mut [f32]) {
    let (k_n, c_n) = (w.rows, w.cols);
    assert_eq!(x.len() % k_n, 0, "x rows must be k_n wide");
    let rows = x.len() / k_n;
    assert_eq!(out.len(), rows * c_n, "out must be rows × c_n");
    out.fill(0.0);
    const KB: usize = 64; // k-block: w-rows chunk resident in L1/L2
    for k0 in (0..k_n).step_by(KB) {
        let k1 = (k0 + KB).min(k_n);
        for r in 0..rows {
            let xrow = &x[r * k_n..(r + 1) * k_n];
            let orow = &mut out[r * c_n..(r + 1) * c_n];
            for (k, &a) in
                xrow.iter().enumerate().take(k1).skip(k0)
            {
                if a != 0.0 {
                    axpy(orow, a, &w.data[k * c_n..(k + 1) * c_n]);
                }
            }
        }
    }
}

/// Euclidean norm.
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Squared L2 distance between two slices.
#[inline]
pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..100).map(|i| i as f32 * 0.1).collect();
        let b: Vec<f32> = (0..100).map(|i| (i as f32 * 0.05).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn dot_handles_short_and_unaligned() {
        assert_eq!(dot(&[], &[]), 0.0);
        assert_eq!(dot(&[2.0], &[3.0]), 6.0);
        let a = [1.0; 13];
        let b = [2.0; 13];
        assert_eq!(dot(&a, &b), 26.0);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 0.5, &[4.0, 8.0]);
        assert_eq!(y, vec![3.0, 6.0]);
    }

    #[test]
    fn dist2_basic() {
        assert_eq!(dist2(&[0.0, 3.0], &[4.0, 0.0]), 25.0);
        assert_eq!(dist2(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn norm2_basic() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn matmul_rows_into_bit_identical_to_per_row_vecmat() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::seed(123);
        // k_n spans multiple 64-wide k-blocks with a ragged tail
        let (rows, k_n, c_n) = (5usize, 150usize, 37usize);
        let w = Tensor2::randn(k_n, c_n, 0.3, &mut rng);
        let mut x: Vec<f32> =
            (0..rows * k_n).map(|_| rng.next_f32_std()).collect();
        // sprinkle exact zeros to exercise the skip path
        for i in (0..x.len()).step_by(11) {
            x[i] = 0.0;
        }
        let mut out = vec![7.0f32; rows * c_n];
        matmul_rows_into(&x, &w, &mut out);
        for r in 0..rows {
            let want = w.vecmat(&x[r * k_n..(r + 1) * k_n]);
            for (a, b) in out[r * c_n..(r + 1) * c_n].iter().zip(&want)
            {
                assert_eq!(a.to_bits(), b.to_bits(), "row {r}");
            }
        }
    }
}
