//! # LOOKAT — Lookup-Optimized Key-Attention for Memory-Efficient Transformers
//!
//! Full-stack reproduction of the LOOKAT paper (Karmore, 2026): KV-cache
//! *key* compression via product quantization (PQ) + asymmetric distance
//! computation (ADC). Attention scores are computed by summing `m` lookup
//! table entries per cached key instead of a `d_k`-wide dot product over
//! dequantized keys — the cache is never decompressed. The §5.2
//! value-side extension is in the serving path too: with
//! `ValueStorage::Pq` the cache stores value codes and attention
//! finishes through a fused blocked weighted decode
//! ([`pq::values::weighted_decode_lanes`]) — neither cache side is
//! ever dequantized per token.
//!
//! ## Architecture (three layers, python never on the request path)
//!
//! * **Layer 3 (this crate)** — serving coordinator: request router,
//!   continuous batcher, PQ KV-cache manager, prefill/decode scheduler,
//!   plus every substrate the paper's evaluation needs (pure-rust GPT-2
//!   style model, K-Means, scalar-quant baselines, metrics, workload
//!   generators, experiment harness).
//! * **Layer 2** — JAX model graphs (`python/compile/model.py`), lowered
//!   once to HLO text in `artifacts/` by `make artifacts`.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/lookat.py`),
//!   called from the L2 graphs; validated against `ref.py` oracles.
//!
//! The [`runtime`] module loads the AOT artifacts and executes them from
//! the rust hot path. It is **feature-gated**: the default build uses a
//! pure-rust interpreter `Runtime` (no external runtime deps, works in
//! offline images), while `--features xla` swaps in the PJRT CPU client
//! (`xla` crate) that compiles and runs the HLO text. Both backends share
//! one calling convention and manifest validation — see `runtime/mod.rs`
//! and README.md §Build matrix.
//!
//! How much each (layer, head, side) compresses is decided at engine
//! build time by a [`coordinator::CompressionPolicy`] — uniform (the
//! paper's single global `m`), calibrated per-(layer,head) subspace
//! budgets under a total bits/token ceiling, or L2-norm token pruning.
//! See `docs/ARCHITECTURE.md` at the repo root for the module map and
//! the life of a decode tick.
//!
//! ## Crate-wide invariants
//!
//! * **Determinism** — every run is a pure function of the config
//!   (seed, backend, policy); no wall-clock, no `HashMap` iteration on
//!   numeric paths. Benches and experiment tables regenerate
//!   bit-identically.
//! * **Subspace accumulation order** — ADC scores, LUT builds and
//!   weighted value decodes always accumulate subspaces in order
//!   `0..m`; f32 addition is not associative, so any reordering is a
//!   bit-parity break (tested in `tests/decode_parity.rs`).
//! * **Compressed-at-rest** — cached keys (and PQ values) exist only
//!   as codes; nothing on the serving path dequantizes a cache block
//!   to score it.
//!
//! ## Quick example
//!
//! ```no_run
//! use lookat::pq::PqCodec;
//! use lookat::attention::{exact_attention, lookat_attention};
//!
//! let d_k = 64;
//! let mut rng = lookat::util::rng::Pcg32::seed(7);
//! let keys: Vec<f32> = (0..512 * d_k).map(|_| rng.next_f32_std()).collect();
//! // Train codebooks on (here: the same) calibration keys, encode, attend.
//! let codec = PqCodec::train(&keys, d_k, 4, 256, &Default::default());
//! let codes = codec.encode_batch(&keys, 512);
//! ```

// The numeric kernels are written as explicit index loops so LLVM's
// autovectorizer sees flat access patterns; silence the style lints that
// would rewrite them into iterator chains.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod attention;
pub mod coordinator;
pub mod experiments;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod pq;
pub mod quant;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod testkit;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
