//! Byte-level tokenizer: every UTF-8 byte is a token, plus BOS/EOS.
//!
//! A byte vocabulary sidesteps the need for a trained BPE merges table
//! (no network access for GPT-2's vocab) while exercising the same code
//! paths; the KV-statistics experiments only need *some* deterministic
//! text→ids mapping.

use super::config::ByteVocab;

/// Stateless byte-level tokenizer.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    pub fn vocab_size(&self) -> usize {
        ByteVocab::SIZE
    }

    /// Encode text to ids, prepending BOS.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids = Vec::with_capacity(text.len() + 1);
        ids.push(ByteVocab::BOS);
        ids.extend(text.as_bytes().iter().map(|&b| b as u32));
        ids
    }

    /// Encode and truncate/pad-free clamp to `max_len` tokens.
    pub fn encode_clamped(&self, text: &str, max_len: usize) -> Vec<u32> {
        let mut ids = self.encode(text);
        ids.truncate(max_len);
        ids
    }

    /// Whether an id is a special (non-byte) token.
    pub fn is_special(&self, id: u32) -> bool {
        id == ByteVocab::BOS || id == ByteVocab::EOS
    }

    /// Decode ids back to text (specials dropped, invalid UTF-8 lossy).
    pub fn decode(&self, ids: &[u32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&id| id < 256)
            .map(|&id| id as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let ids = t.encode("hello world");
        assert_eq!(ids[0], ByteVocab::BOS);
        assert_eq!(ids.len(), 12);
        assert_eq!(t.decode(&ids), "hello world");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new();
        let s = "naïve Σ θ — ok";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn clamping_truncates() {
        let t = ByteTokenizer::new();
        let ids = t.encode_clamped("abcdefgh", 4);
        assert_eq!(ids.len(), 4);
        assert_eq!(t.decode(&ids), "abc"); // BOS + 3 bytes
    }

    #[test]
    fn specials_are_flagged_and_dropped() {
        let t = ByteTokenizer::new();
        assert!(t.is_special(ByteVocab::BOS));
        assert!(t.is_special(ByteVocab::EOS));
        assert!(!t.is_special(65));
        assert_eq!(t.decode(&[ByteVocab::BOS, 65, ByteVocab::EOS]), "A");
    }

    #[test]
    fn all_ids_in_vocab() {
        let t = ByteTokenizer::new();
        for id in t.encode("\u{0000}\u{00FF}ÿ~") {
            assert!((id as usize) < t.vocab_size());
        }
    }
}
