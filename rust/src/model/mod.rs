//! Pure-rust GPT-2-style transformer substrate.
//!
//! Mirrors `python/compile/model.py` op-for-op (pre-LN blocks, fused QKV,
//! tanh-GELU MLP, tied LM head) so the same weights produce the same
//! numerics through either path. Used by the experiment harness (which
//! needs thousands of forwards without PJRT round-trips) and as the
//! non-PJRT compute backend of the serving engine.
//!
//! The paper extracts KV caches from GPT-2's first attention layer
//! (§4.1); [`Gpt2::prefill`] exposes every layer's K/V for that.

mod config;
mod gpt2;
mod tokenizer;
mod weights;

pub use config::ModelConfig;
pub use gpt2::{Gpt2, PrefillOutput};
pub use tokenizer::ByteTokenizer;
pub use weights::{BlockWeights, Weights};
