//! GPT-2-style forward passes (pure rust, mirrors python/compile/model.py).

use super::weights::Weights;
use crate::tensor::{
    gelu_inplace, layernorm, layernorm_into, softmax_inplace, Tensor2,
};

const LN_EPS: f32 = 1e-5;

/// Full-context prefill result.
pub struct PrefillOutput {
    /// logits of the last position, (vocab)
    pub last_logits: Vec<f32>,
    /// per-layer (K, V), each (T × d_model) row-major; head `h` occupies
    /// columns [h·d_k, (h+1)·d_k)
    pub caches: Vec<(Tensor2, Tensor2)>,
    /// per-layer queries, (T × d_model) — kept for the experiment
    /// harness, which replays decode-style attention at every position
    pub queries: Vec<Tensor2>,
    /// final hidden state of the last position (pre-LN_f), (d_model)
    pub last_hidden: Vec<f32>,
}

impl PrefillOutput {
    /// Contiguous (T × d_k) copy of one head's keys from one layer —
    /// the paper's §4.1 KV-extraction operation.
    pub fn head_keys(&self, layer: usize, head: usize, d_k: usize)
        -> Vec<f32>
    {
        Self::extract_head(&self.caches[layer].0, head, d_k)
    }

    /// Contiguous (T × d_k) copy of one head's values from one layer.
    pub fn head_values(&self, layer: usize, head: usize, d_k: usize)
        -> Vec<f32>
    {
        Self::extract_head(&self.caches[layer].1, head, d_k)
    }

    /// Contiguous (T × d_k) copy of one head's queries from one layer.
    pub fn head_queries(&self, layer: usize, head: usize, d_k: usize)
        -> Vec<f32>
    {
        Self::extract_head(&self.queries[layer], head, d_k)
    }

    fn extract_head(t: &Tensor2, head: usize, d_k: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(t.rows * d_k);
        for r in 0..t.rows {
            out.extend_from_slice(
                &t.row(r)[head * d_k..(head + 1) * d_k]);
        }
        out
    }
}

/// The model: weights + forward passes.
pub struct Gpt2 {
    pub weights: Weights,
}

impl Gpt2 {
    pub fn new(weights: Weights) -> Self {
        Self { weights }
    }

    pub fn n_layer(&self) -> usize {
        self.weights.config.n_layer
    }

    pub fn n_head(&self) -> usize {
        self.weights.config.n_head
    }

    pub fn d_head(&self) -> usize {
        self.weights.config.d_head
    }

    pub fn d_model(&self) -> usize {
        self.weights.config.d_model()
    }

    /// Token + position embedding for one token.
    pub fn embed(&self, token: u32, pos: usize) -> Vec<f32> {
        let w = &self.weights;
        assert!(pos < w.config.max_pos, "position {pos} out of range");
        let mut x = w.wte.row(token as usize).to_vec();
        for (xi, pi) in x.iter_mut().zip(w.wpe.row(pos)) {
            *xi += *pi;
        }
        x
    }

    /// LN1 + fused QKV projection for one token in one layer.
    /// Returns (q, k, v), each (H · d_k) with heads contiguous.
    pub fn qkv(&self, layer: usize, x: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let blk = &self.weights.blocks[layer];
        let d = self.d_model();
        let h = layernorm(x, &blk.ln1_g, &blk.ln1_b, LN_EPS);
        let mut qkv = blk.w_qkv.vecmat(&h);
        for (v, b) in qkv.iter_mut().zip(&blk.b_qkv) {
            *v += *b;
        }
        let q = qkv[0..d].to_vec();
        let k = qkv[d..2 * d].to_vec();
        let v = qkv[2 * d..3 * d].to_vec();
        (q, k, v)
    }

    /// Residual attention-out projection + MLP for one token in one layer.
    /// `attn` is the concatenated per-head attention output (d_model).
    pub fn finish_block(&self, layer: usize, x: &[f32], attn: &[f32])
        -> Vec<f32>
    {
        let blk = &self.weights.blocks[layer];
        let mut y = x.to_vec();
        let proj = blk.w_proj.vecmat(attn);
        for ((yi, pi), bi) in y.iter_mut().zip(&proj).zip(&blk.b_proj) {
            *yi += *pi + *bi;
        }
        let h = layernorm(&y, &blk.ln2_g, &blk.ln2_b, LN_EPS);
        let mut ff = blk.w_fc.vecmat(&h);
        for (fi, bi) in ff.iter_mut().zip(&blk.b_fc) {
            *fi += *bi;
        }
        gelu_inplace(&mut ff);
        let out = blk.w_out.vecmat(&ff);
        for ((yi, oi), bi) in y.iter_mut().zip(&out).zip(&blk.b_out) {
            *yi += *oi + *bi;
        }
        y
    }

    /// LN1 + fused QKV projection for a *batch* of token rows in one
    /// layer — the engine's `qkv` stage. Returns a pooled
    /// (rows × 3·d_model) buffer; row `r` holds `[q | k | v]` exactly
    /// as [`Gpt2::qkv`] would produce them (the GEMM accumulates each
    /// output element in the identical order as `vecmat`, so the batch
    /// is bit-identical to per-row calls). Batching is the point: the
    /// (d × 3d) weight matrix streams through memory once per row
    /// *chunk* instead of once per row, which at decode batch width B
    /// cuts weight traffic ~B/threads× — the engine's dominant
    /// bandwidth cost before this refactor. Return the buffer to
    /// `util::threadpool::scratch()` when done.
    pub fn qkv_rows(
        &self,
        layer: usize,
        xs: &[Vec<f32>],
        threads: usize,
    ) -> Vec<f32> {
        let blk = &self.weights.blocks[layer];
        let d = self.d_model();
        let rows = xs.len();
        let pool = crate::util::threadpool::scratch();
        let mut out = pool.take_f32_any(rows * 3 * d);
        if rows == 0 {
            return out;
        }
        let threads = threads.max(1).min(rows);
        let chunk = rows.div_ceil(threads);
        let out_chunks: Vec<std::sync::Mutex<&mut [f32]>> =
            out.chunks_mut(chunk * 3 * d).map(std::sync::Mutex::new).collect();
        crate::util::threadpool::global().run_scoped(
            out_chunks.len(),
            |t| {
                let o = &mut *out_chunks[t].lock().unwrap();
                let r0 = t * chunk;
                let nr = o.len() / (3 * d);
                let pool = crate::util::threadpool::scratch();
                let mut h = pool.take_f32_any(nr * d);
                for j in 0..nr {
                    layernorm_into(
                        &xs[r0 + j],
                        &blk.ln1_g,
                        &blk.ln1_b,
                        LN_EPS,
                        &mut h[j * d..(j + 1) * d],
                    );
                }
                crate::tensor::matmul_rows_into(&h, &blk.w_qkv, o);
                for j in 0..nr {
                    let row = &mut o[j * 3 * d..(j + 1) * 3 * d];
                    for (v, b) in row.iter_mut().zip(&blk.b_qkv) {
                        *v += *b;
                    }
                }
                pool.put_f32(h);
            },
        );
        drop(out_chunks);
        out
    }

    /// Residual attention-out projection + MLP for a *batch* of rows —
    /// the engine's `mlp` stage, bit-identical per row to
    /// [`Gpt2::finish_block`] (same GEMM accumulation order, same
    /// elementwise expressions). `attn` is (rows × d_model) row-major;
    /// returns one pooled hidden vector per row. All staging tensors
    /// (projection, LN2, FF, out) are leased from the scratch pool per
    /// row chunk, so the steady-state tick allocates nothing here.
    pub fn finish_block_rows(
        &self,
        layer: usize,
        xs: &[Vec<f32>],
        attn: &[f32],
        threads: usize,
    ) -> Vec<Vec<f32>> {
        let blk = &self.weights.blocks[layer];
        let d = self.d_model();
        let d_ff = blk.w_fc.cols;
        let rows = xs.len();
        assert_eq!(attn.len(), rows * d, "attn must be rows × d_model");
        let mut ys: Vec<Vec<f32>> = (0..rows).map(|_| Vec::new()).collect();
        if rows == 0 {
            return ys;
        }
        let threads = threads.max(1).min(rows);
        let chunk = rows.div_ceil(threads);
        let y_chunks: Vec<std::sync::Mutex<&mut [Vec<f32>]>> =
            ys.chunks_mut(chunk).map(std::sync::Mutex::new).collect();
        crate::util::threadpool::global().run_scoped(
            y_chunks.len(),
            |t| {
                let slot = &mut *y_chunks[t].lock().unwrap();
                let r0 = t * chunk;
                let nr = slot.len();
                let pool = crate::util::threadpool::scratch();
                // attention-out projection for the chunk
                let mut proj = pool.take_f32_any(nr * d);
                crate::tensor::matmul_rows_into(
                    &attn[r0 * d..(r0 + nr) * d],
                    &blk.w_proj,
                    &mut proj,
                );
                // y = x + proj + b_proj, then LN2 rows
                let mut h = pool.take_f32_any(nr * d);
                for (j, y_slot) in slot.iter_mut().enumerate() {
                    let mut y = pool.take_f32_any(d);
                    y.copy_from_slice(&xs[r0 + j]);
                    let p = &proj[j * d..(j + 1) * d];
                    for ((yi, pi), bi) in
                        y.iter_mut().zip(p).zip(&blk.b_proj)
                    {
                        *yi += *pi + *bi;
                    }
                    layernorm_into(
                        &y,
                        &blk.ln2_g,
                        &blk.ln2_b,
                        LN_EPS,
                        &mut h[j * d..(j + 1) * d],
                    );
                    *y_slot = y;
                }
                // FF up-projection + GELU for the chunk
                let mut ff = pool.take_f32_any(nr * d_ff);
                crate::tensor::matmul_rows_into(&h, &blk.w_fc, &mut ff);
                for j in 0..nr {
                    let row = &mut ff[j * d_ff..(j + 1) * d_ff];
                    for (fi, bi) in row.iter_mut().zip(&blk.b_fc) {
                        *fi += *bi;
                    }
                }
                gelu_inplace(&mut ff);
                // FF down-projection + residual
                let mut o = pool.take_f32_any(nr * d);
                crate::tensor::matmul_rows_into(&ff, &blk.w_out, &mut o);
                for (j, y) in slot.iter_mut().enumerate() {
                    let orow = &o[j * d..(j + 1) * d];
                    for ((yi, oi), bi) in
                        y.iter_mut().zip(orow).zip(&blk.b_out)
                    {
                        *yi += *oi + *bi;
                    }
                }
                pool.put_f32(proj);
                pool.put_f32(h);
                pool.put_f32(ff);
                pool.put_f32(o);
            },
        );
        drop(y_chunks);
        ys
    }

    /// Final layernorm + tied LM head.
    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        let w = &self.weights;
        let h = layernorm(x, &w.ln_f_g, &w.ln_f_b, LN_EPS);
        w.wte.matvec(&h)
    }

    /// Greedy next-token choice from a hidden state.
    pub fn greedy_next(&self, x: &[f32]) -> u32 {
        let logits = self.logits(x);
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        best as u32
    }

    /// Full causal forward over `ids`, producing every layer's K/V cache
    /// (the paper's KV-extraction path) and the last position's logits.
    pub fn prefill(&self, ids: &[u32]) -> PrefillOutput {
        let t_len = ids.len();
        assert!(t_len > 0);
        let cfg = &self.weights.config;
        let d = cfg.d_model();
        let (n_head, d_k) = (cfg.n_head, cfg.d_head);
        let inv_sqrt = 1.0 / (d_k as f32).sqrt();

        let mut x = Tensor2::zeros(t_len, d);
        for (t, &id) in ids.iter().enumerate() {
            let e = self.embed(id, t);
            x.row_mut(t).copy_from_slice(&e);
        }

        let mut caches = Vec::with_capacity(cfg.n_layer);
        let mut queries = Vec::with_capacity(cfg.n_layer);
        for layer in 0..cfg.n_layer {
            let blk = &self.weights.blocks[layer];
            // LN1 + QKV for all positions
            let mut k_cache = Tensor2::zeros(t_len, d);
            let mut v_cache = Tensor2::zeros(t_len, d);
            let mut q_all = Tensor2::zeros(t_len, d);
            for t in 0..t_len {
                let h = layernorm(x.row(t), &blk.ln1_g, &blk.ln1_b, LN_EPS);
                let mut qkv = blk.w_qkv.vecmat(&h);
                for (v, b) in qkv.iter_mut().zip(&blk.b_qkv) {
                    *v += *b;
                }
                q_all.row_mut(t).copy_from_slice(&qkv[0..d]);
                k_cache.row_mut(t).copy_from_slice(&qkv[d..2 * d]);
                v_cache.row_mut(t).copy_from_slice(&qkv[2 * d..3 * d]);
            }
            // causal attention per head
            let mut attn_all = Tensor2::zeros(t_len, d);
            let mut scores = vec![0.0f32; t_len];
            for head in 0..n_head {
                let c0 = head * d_k;
                for t in 0..t_len {
                    let q = &q_all.row(t)[c0..c0 + d_k];
                    for s in 0..=t {
                        let kk = &k_cache.row(s)[c0..c0 + d_k];
                        scores[s] = crate::tensor::dot(q, kk) * inv_sqrt;
                    }
                    softmax_inplace(&mut scores[0..t + 1]);
                    let orow = &mut attn_all.row_mut(t)[c0..c0 + d_k];
                    orow.iter_mut().for_each(|v| *v = 0.0);
                    for s in 0..t + 1 {
                        let a = scores[s];
                        let vv = &v_cache.row(s)[c0..c0 + d_k];
                        for (o, val) in orow.iter_mut().zip(vv) {
                            *o += a * val;
                        }
                    }
                }
            }
            // out-proj + MLP, residuals
            for t in 0..t_len {
                let y = self.finish_block(layer, x.row(t), attn_all.row(t));
                x.row_mut(t).copy_from_slice(&y);
            }
            caches.push((k_cache, v_cache));
            queries.push(q_all);
        }

        let last_hidden = x.row(t_len - 1).to_vec();
        let last_logits = self.logits(&last_hidden);
        PrefillOutput { last_logits, caches, queries, last_hidden }
    }

    /// Incremental decode of one token against explicit per-layer caches
    /// (each (n × d_model) K/V plus current length). Returns the new
    /// hidden state and appends this token's K/V to the caches.
    ///
    /// This is the reference decode path; the serving engine re-implements
    /// the loop against its paged cache + pluggable attention backends.
    pub fn decode_step(
        &self,
        token: u32,
        pos: usize,
        caches: &mut [(Tensor2, Tensor2)],
    ) -> Vec<f32> {
        let cfg = &self.weights.config;
        let (n_head, d_k) = (cfg.n_head, cfg.d_head);
        let inv_sqrt = 1.0 / (d_k as f32).sqrt();
        let mut x = self.embed(token, pos);
        for layer in 0..cfg.n_layer {
            let (q, k_new, v_new) = self.qkv(layer, &x);
            // grow cache tensors by one row
            let (k_cache, v_cache) = &mut caches[layer];
            k_cache.data.extend_from_slice(&k_new);
            k_cache.rows += 1;
            v_cache.data.extend_from_slice(&v_new);
            v_cache.rows += 1;
            let n = k_cache.rows;
            let mut attn = vec![0.0f32; cfg.d_model()];
            let mut scores = vec![0.0f32; n];
            for head in 0..n_head {
                let c0 = head * d_k;
                let qh = &q[c0..c0 + d_k];
                for s in 0..n {
                    scores[s] = crate::tensor::dot(
                        qh, &k_cache.row(s)[c0..c0 + d_k]) * inv_sqrt;
                }
                softmax_inplace(&mut scores);
                let orow = &mut attn[c0..c0 + d_k];
                for s in 0..n {
                    let a = scores[s];
                    let vv = &v_cache.row(s)[c0..c0 + d_k];
                    for (o, val) in orow.iter_mut().zip(vv) {
                        *o += a * val;
                    }
                }
            }
            x = self.finish_block(layer, &x, &attn);
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ByteTokenizer, ModelConfig};

    fn tiny_model() -> Gpt2 {
        Gpt2::new(Weights::random(&ModelConfig::test_tiny(), 42))
    }

    #[test]
    fn prefill_shapes() {
        let m = tiny_model();
        let ids = ByteTokenizer::new().encode("hello world");
        let out = m.prefill(&ids);
        assert_eq!(out.last_logits.len(), m.weights.config.vocab);
        assert_eq!(out.caches.len(), 2);
        assert_eq!(out.caches[0].0.rows, ids.len());
        assert_eq!(out.caches[0].0.cols, m.d_model());
        assert!(out.last_logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn head_extraction_consistent() {
        let m = tiny_model();
        let ids = ByteTokenizer::new().encode("abcdef");
        let out = m.prefill(&ids);
        let d_k = m.d_head();
        let hk = out.head_keys(0, 1, d_k);
        assert_eq!(hk.len(), ids.len() * d_k);
        // row t of head 1 == cols [d_k, 2d_k) of cache row t
        for t in 0..ids.len() {
            assert_eq!(
                &hk[t * d_k..(t + 1) * d_k],
                &out.caches[0].0.row(t)[d_k..2 * d_k]
            );
        }
    }

    #[test]
    fn causality_prefix_invariance() {
        // prefill over a prefix must equal the prefix rows of a longer
        // prefill (causal masking works)
        let m = tiny_model();
        let t = ByteTokenizer::new();
        let long = t.encode("the quick brown fox");
        let short: Vec<u32> = long[..8].to_vec();
        let o_long = m.prefill(&long);
        let o_short = m.prefill(&short);
        for tpos in 0..8 {
            for c in 0..m.d_model() {
                let a = o_long.caches[1].0.at(tpos, c);
                let b = o_short.caches[1].0.at(tpos, c);
                assert!(
                    (a - b).abs() < 1e-4,
                    "K mismatch at t={tpos} c={c}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn decode_step_matches_prefill() {
        // prefill T tokens == prefill T-1 then decode_step for token T
        let m = tiny_model();
        let t = ByteTokenizer::new();
        let ids = t.encode("incremental");
        let tn = ids.len();
        let full = m.prefill(&ids);

        let prefix = m.prefill(&ids[..tn - 1]);
        let mut caches = prefix.caches;
        let hidden = m.decode_step(ids[tn - 1], tn - 1, &mut caches);

        for (h, f) in hidden.iter().zip(&full.last_hidden) {
            assert!((h - f).abs() < 1e-3, "{h} vs {f}");
        }
        // caches should now match the full prefill's caches
        for layer in 0..2 {
            assert_eq!(caches[layer].0.rows, tn);
            for c in 0..m.d_model() {
                let a = caches[layer].0.at(tn - 1, c);
                let b = full.caches[layer].0.at(tn - 1, c);
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn logits_and_greedy_are_stable() {
        let m = tiny_model();
        let ids = ByteTokenizer::new().encode("xyz");
        let a = m.prefill(&ids);
        let b = m.prefill(&ids);
        assert_eq!(a.last_logits, b.last_logits);
        assert_eq!(m.greedy_next(&a.last_hidden),
                   m.greedy_next(&b.last_hidden));
    }

    #[test]
    fn embed_adds_position() {
        let m = tiny_model();
        let a = m.embed(65, 0);
        let b = m.embed(65, 1);
        assert_ne!(a, b);
    }

    #[test]
    #[should_panic(expected = "position")]
    fn embed_rejects_out_of_range_pos() {
        let m = tiny_model();
        m.embed(0, 100_000);
    }

    #[test]
    fn batched_row_stages_bit_identical_to_per_row_paths() {
        // qkv_rows / finish_block_rows are the engine's GEMM-batched
        // stages; every row must match the scalar qkv / finish_block
        // reference bit for bit at every thread width
        let m = tiny_model();
        let d = m.d_model();
        let mut rng = crate::util::rng::Pcg32::seed(2024);
        let rows = 5usize;
        let xs: Vec<Vec<f32>> = (0..rows)
            .map(|_| (0..d).map(|_| rng.next_f32_std()).collect())
            .collect();
        let attn: Vec<f32> =
            (0..rows * d).map(|_| rng.next_f32_std()).collect();
        for layer in 0..2 {
            for threads in [1usize, 2, 4] {
                let qkv = m.qkv_rows(layer, &xs, threads);
                for (r, x) in xs.iter().enumerate() {
                    let (q, k, v) = m.qkv(layer, x);
                    let row = &qkv[r * 3 * d..(r + 1) * 3 * d];
                    assert_eq!(&row[..d], &q[..], "q row {r}");
                    assert_eq!(&row[d..2 * d], &k[..], "k row {r}");
                    assert_eq!(&row[2 * d..], &v[..], "v row {r}");
                }
                let ys = m.finish_block_rows(layer, &xs, &attn, threads);
                for (r, x) in xs.iter().enumerate() {
                    let want = m.finish_block(
                        layer, x, &attn[r * d..(r + 1) * d]);
                    assert_eq!(ys[r], want, "finish row {r}");
                }
            }
        }
    }

    #[test]
    fn key_anisotropy_visible_in_cache() {
        // Cached keys should be far more "clusterable" than an iid
        // Gaussian point set of the same variance (the PQ worst case at
        // fixed variance) — this is the low-intrinsic-dimensionality
        // premise the paper leans on (§1) and the structured init models.
        let m = Gpt2::new(Weights::random(&ModelConfig::test_tiny(), 11));
        let text = crate::workload::Corpus::new(
            crate::workload::Genre::Prose, 3).generate(600);
        let ids = ByteTokenizer::new().encode_clamped(&text, 96);
        let out = m.prefill(&ids);
        let d_k = m.d_head();
        let keys = out.head_keys(0, 0, d_k);
        let n = ids.len();
        let rel_err = |data: &[f32]| {
            let codec = crate::pq::PqCodec::train(
                data, d_k, 4, 16, &Default::default());
            let mse = codec.reconstruction_mse(data, n);
            let var: f64 = data.iter().map(|&x| (x as f64).powi(2))
                .sum::<f64>() / data.len() as f64;
            mse / (var * d_k as f64)
        };
        let mut rng = crate::util::rng::Pcg32::seed(77);
        let gauss: Vec<f32> =
            (0..n * d_k).map(|_| rng.next_f32_std()).collect();
        let ek = rel_err(&keys);
        let eg = rel_err(&gauss);
        assert!(
            ek < eg * 0.5,
            "model keys should quantize much better than iid gaussian: \
             {ek} vs {eg}"
        );
    }
}
