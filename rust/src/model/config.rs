//! Model hyper-parameters.

use crate::util::json::Json;

/// GPT-2-style architecture configuration.
///
/// `gpt2_layer0()` is the experiment default: the paper's head geometry
/// (H=12, d_k=64, so d_model=768) but shallow, because §4.1 extracts KV
/// caches from layer 0 only; `gpt2_small()` is the full 12-layer shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub max_pos: usize,
}

impl ModelConfig {
    pub fn d_model(&self) -> usize {
        self.n_head * self.d_head
    }

    /// Paper geometry, shallow depth (experiments use layer 0 only).
    pub fn gpt2_layer0() -> Self {
        Self {
            vocab: ByteVocab::SIZE,
            n_layer: 2,
            n_head: 12,
            d_head: 64,
            d_ff: 3072,
            max_pos: 1024,
        }
    }

    /// Full GPT-2-small shape (slow on one core; examples only).
    pub fn gpt2_small() -> Self {
        Self { n_layer: 12, ..Self::gpt2_layer0() }
    }

    /// Tiny config for unit tests.
    pub fn test_tiny() -> Self {
        Self {
            vocab: ByteVocab::SIZE,
            n_layer: 2,
            n_head: 4,
            d_head: 16,
            d_ff: 128,
            max_pos: 128,
        }
    }

    /// Parameter count (tied LM head).
    pub fn num_params(&self) -> usize {
        let d = self.d_model();
        let per_block = 2 * d            // ln1
            + d * 3 * d + 3 * d          // qkv
            + d * d + d                  // proj
            + 2 * d                      // ln2
            + d * self.d_ff + self.d_ff  // fc
            + self.d_ff * d + d; // out
        self.vocab * d + self.max_pos * d + per_block * self.n_layer + 2 * d
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("vocab", Json::Num(self.vocab as f64)),
            ("n_layer", Json::Num(self.n_layer as f64)),
            ("n_head", Json::Num(self.n_head as f64)),
            ("d_head", Json::Num(self.d_head as f64)),
            ("d_ff", Json::Num(self.d_ff as f64)),
            ("max_pos", Json::Num(self.max_pos as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ModelConfig> {
        Some(ModelConfig {
            vocab: j.get("vocab")?.as_usize()?,
            n_layer: j.get("n_layer")?.as_usize()?,
            n_head: j.get("n_head")?.as_usize()?,
            d_head: j.get("d_head")?.as_usize()?,
            d_ff: j.get("d_ff")?.as_usize()?,
            max_pos: j.get("max_pos")?.as_usize()?,
        })
    }
}

/// Byte-level vocabulary constants (see tokenizer.rs).
pub struct ByteVocab;

impl ByteVocab {
    /// 256 bytes + BOS + EOS, rounded up for clean shapes.
    pub const SIZE: usize = 260;
    pub const BOS: u32 = 256;
    pub const EOS: u32 = 257;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn d_model_and_params() {
        let c = ModelConfig::gpt2_small();
        assert_eq!(c.d_model(), 768);
        // GPT-2 small is ~124M with a 50k vocab; with our byte vocab the
        // total lands near 85M — sanity-band check only
        let p = c.num_params();
        assert!(p > 80_000_000 && p < 130_000_000, "params {p}");
    }

    #[test]
    fn layer0_matches_paper_geometry() {
        let c = ModelConfig::gpt2_layer0();
        assert_eq!(c.n_head, 12);
        assert_eq!(c.d_head, 64);
        assert_eq!(c.d_model(), 768);
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::test_tiny();
        let j = c.to_json();
        assert_eq!(ModelConfig::from_json(&j), Some(c));
    }
}
