//! Model weights: structured random initialization + binary persistence.
//!
//! Initialization is *anisotropic* on the key projection: the K block of
//! `w_qkv` is low-rank-plus-noise, concentrating key energy in a small
//! subspace. Pretrained transformers exhibit exactly this low intrinsic
//! dimensionality (paper §1 cites Aghajanyan et al. 2021 as the reason
//! PQ codebooks capture keys well); a plain isotropic Gaussian would be
//! the *hardest* case for PQ and would understate the paper's effect.
//! See DESIGN.md §Environment constraints.

use std::io::{Read, Write};

use anyhow::{bail, Context};

use super::config::ModelConfig;
use crate::tensor::Tensor2;
use crate::util::rng::Pcg32;

/// Per-block parameters. Field order matches the python convention in
/// python/compile/model.py (and the block artifact input order).
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    /// (d_model, 3·d_model) fused QKV
    pub w_qkv: Tensor2,
    pub b_qkv: Vec<f32>,
    /// (d_model, d_model)
    pub w_proj: Tensor2,
    pub b_proj: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    /// (d_model, d_ff)
    pub w_fc: Tensor2,
    pub b_fc: Vec<f32>,
    /// (d_ff, d_model)
    pub w_out: Tensor2,
    pub b_out: Vec<f32>,
}

/// Full model parameters (LM head tied to `wte`).
pub struct Weights {
    pub config: ModelConfig,
    /// (vocab, d_model)
    pub wte: Tensor2,
    /// (max_pos, d_model)
    pub wpe: Tensor2,
    pub blocks: Vec<BlockWeights>,
    pub ln_f_g: Vec<f32>,
    pub ln_f_b: Vec<f32>,
}

const MAGIC: &[u8; 8] = b"LOOKATW1";

/// Low-rank-plus-noise matrix: A(r) @ B(r) * scale + eps * G.
fn low_rank_noise(
    rows: usize,
    cols: usize,
    rank: usize,
    scale: f32,
    eps: f32,
    rng: &mut Pcg32,
) -> Tensor2 {
    let a = Tensor2::randn(rows, rank, 1.0 / (rank as f32).sqrt(), rng);
    let b = Tensor2::randn(rank, cols, scale, rng);
    let mut m = a.matmul(&b);
    for v in m.data.iter_mut() {
        *v += rng.next_normal(0.0, eps);
    }
    m
}

impl Weights {
    /// Structured random initialization (see module docs).
    pub fn random(config: &ModelConfig, seed: u64) -> Weights {
        let mut rng = Pcg32::seed(seed);
        let d = config.d_model();
        let sigma = 1.0 / (d as f32).sqrt();
        let wte = Tensor2::randn(config.vocab, d, sigma * 4.0, &mut rng);
        let wpe = Tensor2::randn(config.max_pos, d, sigma, &mut rng);
        let mut blocks = Vec::with_capacity(config.n_layer);
        for layer in 0..config.n_layer {
            let mut lrng = rng.split(layer as u64);
            blocks.push(Self::random_block(config, &mut lrng));
        }
        Weights {
            config: config.clone(),
            wte,
            wpe,
            blocks,
            ln_f_g: vec![1.0; d],
            ln_f_b: vec![0.0; d],
        }
    }

    fn random_block(config: &ModelConfig, rng: &mut Pcg32) -> BlockWeights {
        let d = config.d_model();
        let sigma = 1.0 / (d as f32).sqrt();
        // Q and V blocks: isotropic. K block: low-rank + noise so cached
        // keys live near a low-dimensional subspace (see module docs).
        let w_q = Tensor2::randn(d, d, sigma, rng);
        let k_rank = (config.d_head / 4).max(2) * config.n_head;
        let w_k = low_rank_noise(d, d, k_rank, sigma * 1.5, sigma * 0.15, rng);
        let w_v = Tensor2::randn(d, d, sigma, rng);
        // fuse into (d, 3d): columns [Q | K | V]
        let mut w_qkv = Tensor2::zeros(d, 3 * d);
        for r in 0..d {
            w_qkv.row_mut(r)[0..d].copy_from_slice(w_q.row(r));
            w_qkv.row_mut(r)[d..2 * d].copy_from_slice(w_k.row(r));
            w_qkv.row_mut(r)[2 * d..3 * d].copy_from_slice(w_v.row(r));
        }
        BlockWeights {
            ln1_g: vec![1.0; d],
            ln1_b: vec![0.0; d],
            w_qkv,
            b_qkv: vec![0.0; 3 * d],
            w_proj: Tensor2::randn(d, d, sigma, rng),
            b_proj: vec![0.0; d],
            ln2_g: vec![1.0; d],
            ln2_b: vec![0.0; d],
            w_fc: Tensor2::randn(d, config.d_ff, sigma, rng),
            b_fc: vec![0.0; config.d_ff],
            w_out: Tensor2::randn(
                config.d_ff,
                d,
                1.0 / (config.d_ff as f32).sqrt(),
                rng,
            ),
            b_out: vec![0.0; d],
        }
    }

    // -- persistence -------------------------------------------------------

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        let cfg = self.config.to_json().to_string();
        w.write_all(&(cfg.len() as u64).to_le_bytes())?;
        w.write_all(cfg.as_bytes())?;
        let write_f32s = |w: &mut dyn Write, xs: &[f32]| -> anyhow::Result<()> {
            let mut buf = Vec::with_capacity(xs.len() * 4);
            for &x in xs {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(&buf)?;
            Ok(())
        };
        write_f32s(&mut w, &self.wte.data)?;
        write_f32s(&mut w, &self.wpe.data)?;
        for b in &self.blocks {
            for xs in b.flat_order() {
                write_f32s(&mut w, xs)?;
            }
        }
        write_f32s(&mut w, &self.ln_f_g)?;
        write_f32s(&mut w, &self.ln_f_b)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Weights> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("weights magic")?;
        if &magic != MAGIC {
            bail!("not a LOOKAT weights file");
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let cfg_len = u64::from_le_bytes(b8) as usize;
        if cfg_len > 1 << 20 {
            bail!("unreasonable config length");
        }
        let mut cfg_buf = vec![0u8; cfg_len];
        r.read_exact(&mut cfg_buf)?;
        let cfg_json = crate::util::json::Json::parse(
            std::str::from_utf8(&cfg_buf)?,
        )?;
        let config = ModelConfig::from_json(&cfg_json)
            .context("bad config json")?;

        let read_f32s = |r: &mut dyn Read, n: usize| -> anyhow::Result<Vec<f32>> {
            let mut buf = vec![0u8; n * 4];
            r.read_exact(&mut buf)?;
            Ok(buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        let d = config.d_model();
        let wte = Tensor2::from_vec(
            config.vocab, d, read_f32s(&mut r, config.vocab * d)?);
        let wpe = Tensor2::from_vec(
            config.max_pos, d, read_f32s(&mut r, config.max_pos * d)?);
        let mut blocks = Vec::with_capacity(config.n_layer);
        for _ in 0..config.n_layer {
            blocks.push(BlockWeights {
                ln1_g: read_f32s(&mut r, d)?,
                ln1_b: read_f32s(&mut r, d)?,
                w_qkv: Tensor2::from_vec(d, 3 * d,
                                         read_f32s(&mut r, d * 3 * d)?),
                b_qkv: read_f32s(&mut r, 3 * d)?,
                w_proj: Tensor2::from_vec(d, d, read_f32s(&mut r, d * d)?),
                b_proj: read_f32s(&mut r, d)?,
                ln2_g: read_f32s(&mut r, d)?,
                ln2_b: read_f32s(&mut r, d)?,
                w_fc: Tensor2::from_vec(d, config.d_ff,
                                        read_f32s(&mut r, d * config.d_ff)?),
                b_fc: read_f32s(&mut r, config.d_ff)?,
                w_out: Tensor2::from_vec(config.d_ff, d,
                                         read_f32s(&mut r, config.d_ff * d)?),
                b_out: read_f32s(&mut r, d)?,
            });
        }
        let ln_f_g = read_f32s(&mut r, d)?;
        let ln_f_b = read_f32s(&mut r, d)?;
        Ok(Weights { config, wte, wpe, blocks, ln_f_g, ln_f_b })
    }
}

impl BlockWeights {
    /// Parameter slices in the canonical (python-matching) order.
    pub fn flat_order(&self) -> Vec<&[f32]> {
        vec![
            &self.ln1_g, &self.ln1_b, &self.w_qkv.data, &self.b_qkv,
            &self.w_proj.data, &self.b_proj, &self.ln2_g, &self.ln2_b,
            &self.w_fc.data, &self.b_fc, &self.w_out.data, &self.b_out,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_init_shapes() {
        let cfg = ModelConfig::test_tiny();
        let w = Weights::random(&cfg, 1);
        let d = cfg.d_model();
        assert_eq!(w.wte.rows, cfg.vocab);
        assert_eq!(w.blocks.len(), cfg.n_layer);
        assert_eq!(w.blocks[0].w_qkv.cols, 3 * d);
        assert_eq!(w.blocks[0].w_fc.cols, cfg.d_ff);
    }

    #[test]
    fn init_is_deterministic() {
        let cfg = ModelConfig::test_tiny();
        let a = Weights::random(&cfg, 7);
        let b = Weights::random(&cfg, 7);
        assert_eq!(a.wte.data, b.wte.data);
        assert_eq!(a.blocks[1].w_qkv.data, b.blocks[1].w_qkv.data);
        let c = Weights::random(&cfg, 8);
        assert_ne!(a.wte.data, c.wte.data);
    }

    #[test]
    fn key_block_is_anisotropic() {
        // effective rank of K block should be well below Q block's
        let cfg = ModelConfig::test_tiny();
        let w = Weights::random(&cfg, 3);
        let d = cfg.d_model();
        let spectral_spread = |cols: std::ops::Range<usize>| {
            // cheap proxy: column-norm variance of the block
            let blk = &w.blocks[0].w_qkv;
            let norms: Vec<f64> = cols
                .map(|c| {
                    (0..d)
                        .map(|r| (blk.at(r, c) as f64).powi(2))
                        .sum::<f64>()
                        .sqrt()
                })
                .collect();
            let m = norms.iter().sum::<f64>() / norms.len() as f64;
            norms.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
                / norms.len() as f64
        };
        let q_spread = spectral_spread(0..d);
        let k_spread = spectral_spread(d..2 * d);
        assert!(
            k_spread > q_spread * 2.0,
            "K block should be structured: {k_spread} vs {q_spread}"
        );
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::test_tiny();
        let w = Weights::random(&cfg, 5);
        let dir = std::env::temp_dir().join("lookat-test-weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        w.save(&path).unwrap();
        let back = Weights::load(&path).unwrap();
        assert_eq!(back.config, cfg);
        assert_eq!(back.wte.data, w.wte.data);
        assert_eq!(back.wpe.data, w.wpe.data);
        assert_eq!(back.ln_f_g, w.ln_f_g);
        for (a, b) in back.blocks.iter().zip(&w.blocks) {
            assert_eq!(a.w_qkv.data, b.w_qkv.data);
            assert_eq!(a.w_out.data, b.w_out.data);
            assert_eq!(a.b_fc, b.b_fc);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("lookat-test-weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.bin");
        std::fs::write(&path, b"garbage data here").unwrap();
        assert!(Weights::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flat_order_has_twelve_entries() {
        let cfg = ModelConfig::test_tiny();
        let w = Weights::random(&cfg, 9);
        assert_eq!(w.blocks[0].flat_order().len(), 12);
    }
}
