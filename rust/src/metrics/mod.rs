//! Evaluation metrics from paper §4.2: cosine similarity, KL divergence,
//! Spearman rank correlation and Top-k overlap, plus an aggregate
//! [`FidelityReport`] used by every experiment table.

use crate::util::json::Json;

/// Cosine similarity between two vectors (§4.2.1). Returns 0 when either
/// vector is all-zero (direction undefined).
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na <= 0.0 || nb <= 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// KL(p ‖ q) in nats over two distributions (§4.2.2). Inputs are
/// re-normalized; q is floored at `eps` to keep the divergence finite
/// (matching standard practice for attention-distribution comparisons).
pub fn kl_divergence(p: &[f32], q: &[f32], eps: f64) -> f64 {
    assert_eq!(p.len(), q.len());
    let sp: f64 = p.iter().map(|&x| x as f64).sum();
    let sq: f64 = q.iter().map(|&x| x as f64).sum();
    assert!(sp > 0.0 && sq > 0.0, "distributions must have mass");
    let mut kl = 0.0f64;
    for (&pi, &qi) in p.iter().zip(q.iter()) {
        let pn = pi as f64 / sp;
        if pn <= 0.0 {
            continue;
        }
        let qn = (qi as f64 / sq).max(eps);
        kl += pn * (pn / qn).ln();
    }
    kl.max(0.0)
}

/// Fractional ranks with average-rank tie handling.
fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        // average rank for the tie group [i, j]
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &id in &idx[i..=j] {
            r[id] = avg;
        }
        i = j + 1;
    }
    r
}

/// Spearman rank correlation ρ (§4.2.3), ties handled by average ranks
/// (Pearson correlation of the rank vectors).
pub fn spearman_rho(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let ra = ranks(a);
    let rb = ranks(b);
    pearson(&ra, &rb)
}

/// Pearson correlation of two equal-length samples.
pub fn pearson(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().sum::<f64>() / n;
    let mb = b.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b.iter()) {
        cov += (x - ma) * (y - mb);
        va += (x - ma) * (x - ma);
        vb += (y - mb) * (y - mb);
    }
    if va <= 0.0 || vb <= 0.0 {
        // a constant sequence has undefined correlation; treat identical
        // constants as perfectly correlated (both rankings are trivial)
        return if va == vb { 1.0 } else { 0.0 };
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Indices of the top-k values (descending).
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
    idx.truncate(k);
    idx
}

/// Top-k overlap |TopK(a) ∩ TopK(b)| / k (§4.2.4, k = 5 in the paper).
pub fn top_k_overlap(a: &[f32], b: &[f32], k: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    let k = k.min(a.len());
    if k == 0 {
        return 1.0;
    }
    let ta = top_k_indices(a, k);
    let tb = top_k_indices(b, k);
    let set: std::collections::HashSet<usize> = ta.into_iter().collect();
    let inter = tb.iter().filter(|i| set.contains(i)).count();
    inter as f64 / k as f64
}

/// Aggregate fidelity of one approximate attention output vs FP16
/// reference — one row of the paper's Table 1 for one sample.
#[derive(Clone, Debug, Default)]
pub struct FidelityReport {
    pub cosine: f64,
    pub kl: f64,
    pub spearman: f64,
    pub top5: f64,
}

impl FidelityReport {
    /// Compare attention *outputs* (cosine) and *weights* (KL, ρ, top-5).
    pub fn compare(
        out_ref: &[f32],
        out_approx: &[f32],
        weights_ref: &[f32],
        weights_approx: &[f32],
    ) -> FidelityReport {
        let wr: Vec<f64> = weights_ref.iter().map(|&x| x as f64).collect();
        let wa: Vec<f64> =
            weights_approx.iter().map(|&x| x as f64).collect();
        FidelityReport {
            cosine: cosine_similarity(out_ref, out_approx),
            kl: kl_divergence(weights_ref, weights_approx, 1e-10),
            spearman: spearman_rho(&wr, &wa),
            top5: top_k_overlap(weights_ref, weights_approx, 5),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("cosine", Json::Num(self.cosine)),
            ("kl", Json::Num(self.kl)),
            ("spearman", Json::Num(self.spearman)),
            ("top5", Json::Num(self.top5)),
        ])
    }
}

/// Mean ± std over many reports (paper reports mean±std over 3 samples).
#[derive(Clone, Debug, Default)]
pub struct AggregateFidelity {
    pub cosine: (f64, f64),
    pub kl: (f64, f64),
    pub spearman: (f64, f64),
    pub top5: (f64, f64),
    pub n: usize,
}

impl AggregateFidelity {
    pub fn of(reports: &[FidelityReport]) -> AggregateFidelity {
        use crate::util::stats::mean_std;
        assert!(!reports.is_empty());
        let grab = |f: fn(&FidelityReport) -> f64| {
            let v: Vec<f64> = reports.iter().map(f).collect();
            mean_std(&v)
        };
        AggregateFidelity {
            cosine: grab(|r| r.cosine),
            kl: grab(|r| r.kl),
            spearman: grab(|r| r.spearman),
            top5: grab(|r| r.top5),
            n: reports.len(),
        }
    }

    pub fn to_json(&self) -> Json {
        let pair = |(m, s): (f64, f64)| {
            Json::Arr(vec![Json::Num(m), Json::Num(s)])
        };
        Json::from_pairs(vec![
            ("cosine", pair(self.cosine)),
            ("kl", pair(self.kl)),
            ("spearman", pair(self.spearman)),
            ("top5", pair(self.top5)),
            ("n", Json::Num(self.n as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_extremes() {
        assert!((cosine_similarity(&[1., 0.], &[1., 0.]) - 1.0).abs() < 1e-12);
        assert!((cosine_similarity(&[1., 0.], &[-1., 0.]) + 1.0).abs() < 1e-12);
        assert!(cosine_similarity(&[1., 0.], &[0., 1.]).abs() < 1e-12);
        assert_eq!(cosine_similarity(&[0., 0.], &[1., 1.]), 0.0);
    }

    #[test]
    fn cosine_scale_invariant() {
        let a = [0.3f32, -1.2, 2.0];
        let b = [0.6f32, -2.4, 4.0];
        assert!((cosine_similarity(&a, &b) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kl_zero_for_identical() {
        let p = [0.2f32, 0.3, 0.5];
        assert!(kl_divergence(&p, &p, 1e-12).abs() < 1e-12);
    }

    #[test]
    fn kl_positive_and_asymmetric() {
        let p = [0.9f32, 0.05, 0.05];
        let q = [0.2f32, 0.4, 0.4];
        let pq = kl_divergence(&p, &q, 1e-12);
        let qp = kl_divergence(&q, &p, 1e-12);
        assert!(pq > 0.0);
        assert!(qp > 0.0);
        assert!((pq - qp).abs() > 1e-6, "KL should be asymmetric here");
    }

    #[test]
    fn kl_known_value() {
        // KL([1,0] || [0.5,0.5]) = ln 2
        let kl = kl_divergence(&[1.0, 0.0], &[0.5, 0.5], 1e-12);
        assert!((kl - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn kl_renormalizes_inputs() {
        let a = kl_divergence(&[2.0, 6.0], &[1.0, 1.0], 1e-12);
        let b = kl_divergence(&[0.25, 0.75], &[0.5, 0.5], 1e-12);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn spearman_perfect_and_reversed() {
        let a = [1.0, 2.0, 3.0, 4.0];
        let up = [10.0, 20.0, 30.0, 40.0];
        let down = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman_rho(&a, &up) - 1.0).abs() < 1e-12);
        assert!((spearman_rho(&a, &down) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_is_rank_based() {
        // monotone transform changes values but not ranks
        let a = [0.1f64, 0.5, 0.2, 0.9];
        let b: Vec<f64> = a.iter().map(|x| x.exp() * 100.0).collect();
        assert!((spearman_rho(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let a = [1.0, 1.0, 2.0, 3.0];
        let b = [1.0, 1.0, 2.0, 3.0];
        assert!((spearman_rho(&a, &b) - 1.0).abs() < 1e-12);
        // all-constant vs varying: defined as 0 (no rank information)
        let c = [5.0, 5.0, 5.0, 5.0];
        assert_eq!(spearman_rho(&c, &a), 0.0);
    }

    #[test]
    fn ranks_average_ties() {
        let r = ranks(&[10.0, 20.0, 20.0, 30.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }

    #[test]
    fn top_k_overlap_basics() {
        let a = [9.0f32, 8.0, 7.0, 1.0, 0.5, 0.1];
        let b = [9.1f32, 8.2, 6.9, 1.1, 0.4, 0.2];
        assert_eq!(top_k_overlap(&a, &b, 3), 1.0);
        let c = [0.0f32, 0.1, 0.2, 9.0, 9.1, 9.2];
        assert_eq!(top_k_overlap(&a, &c, 3), 0.0);
    }

    #[test]
    fn top_k_overlap_partial() {
        let a = [5.0f32, 4.0, 3.0, 2.0, 1.0];
        let b = [5.0f32, 4.0, 0.0, 2.0, 3.0]; // top-3 of b = {0,1,4}
        let ov = top_k_overlap(&a, &b, 3);
        assert!((ov - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn top_k_larger_than_len_is_full_overlap() {
        let a = [1.0f32, 2.0];
        assert_eq!(top_k_overlap(&a, &a, 10), 1.0);
    }

    #[test]
    fn fidelity_report_identity() {
        let out = [0.5f32, -0.2, 0.8];
        let w = [0.1f32, 0.7, 0.2];
        let r = FidelityReport::compare(&out, &out, &w, &w);
        assert!((r.cosine - 1.0).abs() < 1e-9);
        assert!(r.kl.abs() < 1e-9);
        assert!((r.spearman - 1.0).abs() < 1e-9);
        assert_eq!(r.top5, 1.0);
    }

    #[test]
    fn aggregate_mean_std() {
        let reports = vec![
            FidelityReport { cosine: 0.9, kl: 1.0, spearman: 0.8, top5: 1.0 },
            FidelityReport { cosine: 0.7, kl: 3.0, spearman: 0.6, top5: 0.5 },
        ];
        let agg = AggregateFidelity::of(&reports);
        assert!((agg.cosine.0 - 0.8).abs() < 1e-12);
        assert!((agg.kl.0 - 2.0).abs() < 1e-12);
        assert!(agg.cosine.1 > 0.0);
        assert_eq!(agg.n, 2);
    }
}
