//! Pure-rust interpreter `Runtime` — the default (no `xla` feature)
//! backend. See `runtime/mod.rs` for the backend contract.
//!
//! Instead of compiling the HLO text, this backend evaluates the known
//! artifact *kinds* directly from the manifest contract, with the same
//! math as the L3 hot path (`attention`, `pq::LookupTable`). Shape and
//! dtype validation is shared with the PJRT executor, so the `Pjrt*`
//! engine backends and the integration tests behave identically up to
//! numerics — which the interpreter reproduces bit-for-bit against the
//! pure-rust reference because it *is* the pure-rust reference.

use std::collections::HashSet;
use std::path::Path;

use anyhow::{bail, Context};

use super::artifact::{ArtifactSpec, Manifest};
use super::{validate_inputs, InputArg};
use crate::tensor::{dot, softmax_inplace};

/// Interpreter runtime over one artifacts directory.
pub struct Runtime {
    pub manifest: Manifest,
    loaded: HashSet<String>,
}

impl Runtime {
    /// Open an artifacts directory (must contain manifest.json).
    pub fn open(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        crate::log_info!(
            "interp runtime up (xla feature off): artifacts={}",
            manifest.artifacts.len()
        );
        Ok(Runtime { manifest, loaded: HashSet::new() })
    }

    /// Default artifacts directory (rust/artifacts), if built.
    pub fn open_default() -> anyhow::Result<Runtime> {
        Self::open(&super::default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        "interp-cpu".to_string()
    }

    /// Resolve an artifact; returns its spec. (The interpreter has no
    /// compile step — this only checks the manifest entry exists.)
    pub fn load(&mut self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        let spec = self
            .manifest
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))?;
        self.loaded.insert(name.to_string());
        Ok(spec)
    }

    /// Execute an artifact with shape/dtype validation against the
    /// manifest. Returns one flat f32 vector per declared output.
    ///
    /// This is the default backend's per-decode-step path, so the spec
    /// is used by shared borrow — no per-call clone of the shape/meta
    /// tree.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[InputArg<'_>],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        if self.manifest.get(name).is_some() {
            self.loaded.insert(name.to_string());
        }
        let spec = self
            .manifest
            .get(name)
            .with_context(|| format!("unknown artifact '{name}'"))?;
        validate_inputs(spec, inputs)?;
        let outs = match spec.kind() {
            "attn_fp16" => vec![attn_fp16(spec, inputs)?],
            "attn_lookat" => vec![attn_lookat(spec, inputs)?],
            "lut_build" => vec![lut_build(spec, inputs)?],
            "adc_scores" => vec![adc_scores(spec, inputs)?],
            other => bail!(
                "{name}: artifact kind '{other}' is not supported by the \
                 interpreter runtime — build with --features xla"
            ),
        };
        if outs.len() != spec.outputs.len() {
            bail!(
                "{name}: interpreter produced {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            );
        }
        for (v, ospec) in outs.iter().zip(&spec.outputs) {
            if v.len() != ospec.elements() {
                bail!(
                    "{name}.{}: output has {} elements, expected {}",
                    ospec.name,
                    v.len(),
                    ospec.elements()
                );
            }
        }
        Ok(outs)
    }

    /// Names of artifacts resolved so far.
    pub fn loaded(&self) -> Vec<&str> {
        self.loaded.iter().map(|s| s.as_str()).collect()
    }
}

fn f32_input<'a>(
    arg: &InputArg<'a>,
    what: &str,
) -> anyhow::Result<&'a [f32]> {
    match arg {
        InputArg::F32(d) => Ok(*d),
        InputArg::I32(_) => bail!("{what}: expected f32 input"),
    }
}

fn i32_input<'a>(
    arg: &InputArg<'a>,
    what: &str,
) -> anyhow::Result<&'a [i32]> {
    match arg {
        InputArg::I32(d) => Ok(*d),
        InputArg::F32(_) => bail!("{what}: expected i32 input"),
    }
}

/// Guard against manifest-internal inconsistency: `validate_inputs`
/// checks the caller's inputs *against* the spec, but the spec itself is
/// external JSON — a kind with the wrong input count must error, not
/// panic on a fixed-position index below.
fn expect_arity(
    spec: &ArtifactSpec,
    kind: &str,
    n: usize,
) -> anyhow::Result<()> {
    if spec.inputs.len() != n {
        bail!(
            "{}: kind '{kind}' needs {n} inputs, manifest declares {}",
            spec.name,
            spec.inputs.len()
        );
    }
    Ok(())
}

/// LUT kernel shared by `attn_lookat` and `lut_build`:
/// `out[i*K + c] = q^(i) · cb[i, c, :]` over a flat (m, K, d_sub)
/// codebook.
fn build_lut_into(
    q: &[f32],
    cb: &[f32],
    m: usize,
    kk: usize,
    d_sub: usize,
    out: &mut [f32],
) {
    for i in 0..m {
        let q_sub = &q[i * d_sub..(i + 1) * d_sub];
        for c in 0..kk {
            let base = (i * kk + c) * d_sub;
            out[i * kk + c] = dot(q_sub, &cb[base..base + d_sub]);
        }
    }
}

/// Masked single-query attention tail shared by both attention kinds:
/// scale by 1/sqrt(d_k), softmax over the mask-selected positions,
/// weighted value sum. Writes the (d_k) context into `out`.
fn masked_attention_tail(
    scores: &[f32],
    values: &[f32],
    mask: &[f32],
    d_k: usize,
    out: &mut [f32],
) {
    let inv = 1.0 / (d_k as f32).sqrt();
    // gather valid positions (mask != 0), softmax over them only —
    // identical to running exact attention over the valid prefix
    let valid: Vec<usize> =
        (0..mask.len()).filter(|&l| mask[l] != 0.0).collect();
    let mut s: Vec<f32> =
        valid.iter().map(|&l| scores[l] * inv).collect();
    softmax_inplace(&mut s);
    out.iter_mut().for_each(|v| *v = 0.0);
    for (i, &l) in valid.iter().enumerate() {
        let a = s[i];
        if a > 0.0 {
            crate::tensor::axpy(out, a, &values[l * d_k..(l + 1) * d_k]);
        }
    }
}

/// kind=attn_fp16 — inputs (q[H,dk], k[H,L,dk], v[H,L,dk], mask[L]),
/// output (H,dk).
fn attn_fp16(
    spec: &ArtifactSpec,
    inputs: &[InputArg<'_>],
) -> anyhow::Result<Vec<f32>> {
    expect_arity(spec, "attn_fp16", 4)?;
    let qs = &spec.inputs[0].shape;
    if qs.len() != 2 || spec.inputs[1].shape.len() != 3 {
        bail!("{}: unexpected attn_fp16 shapes", spec.name);
    }
    let (h, d_k) = (qs[0], qs[1]);
    let l = spec.inputs[1].shape[1];
    if spec.inputs[1].elements() != h * l * d_k
        || spec.inputs[2].elements() != h * l * d_k
        || spec.inputs[3].elements() != l
    {
        bail!("{}: k/v/mask shapes disagree with q in manifest", spec.name);
    }
    let q = f32_input(&inputs[0], "q")?;
    let k = f32_input(&inputs[1], "k")?;
    let v = f32_input(&inputs[2], "v")?;
    let mask = f32_input(&inputs[3], "mask")?;
    let mut out = vec![0.0f32; h * d_k];
    let mut scores = vec![0.0f32; l];
    for head in 0..h {
        let qh = &q[head * d_k..(head + 1) * d_k];
        let kh = &k[head * l * d_k..(head + 1) * l * d_k];
        for (t, s) in scores.iter_mut().enumerate() {
            *s = dot(qh, &kh[t * d_k..(t + 1) * d_k]);
        }
        masked_attention_tail(
            &scores,
            &v[head * l * d_k..(head + 1) * l * d_k],
            mask,
            d_k,
            &mut out[head * d_k..(head + 1) * d_k],
        );
    }
    Ok(out)
}

/// kind=attn_lookat — inputs (q[H,dk], codes[H,L,m], cbs[H,m,K,dsub],
/// v[H,L,dk], mask[L]), output (H,dk).
fn attn_lookat(
    spec: &ArtifactSpec,
    inputs: &[InputArg<'_>],
) -> anyhow::Result<Vec<f32>> {
    expect_arity(spec, "attn_lookat", 5)?;
    let qs = &spec.inputs[0].shape;
    let cs = &spec.inputs[1].shape;
    let bs = &spec.inputs[2].shape;
    if qs.len() != 2 || cs.len() != 3 || bs.len() != 4 {
        bail!("{}: unexpected attn_lookat shapes", spec.name);
    }
    let (h, d_k) = (qs[0], qs[1]);
    let (l, m) = (cs[1], cs[2]);
    let (kk, d_sub) = (bs[2], bs[3]);
    if m * d_sub != d_k {
        bail!("{}: m*d_sub != d_k in manifest", spec.name);
    }
    if bs[1] != m || cs[0] != h || bs[0] != h {
        bail!(
            "{}: codes ({}x{l}x{}) and codebooks ({}x{}x{kk}x{d_sub}) \
             disagree with q ({h}x{d_k}) in manifest",
            spec.name, cs[0], m, bs[0], bs[1]
        );
    }
    if spec.inputs[3].elements() != h * l * d_k
        || spec.inputs[4].elements() != l
    {
        bail!("{}: v/mask shapes disagree with q in manifest", spec.name);
    }
    let q = f32_input(&inputs[0], "q")?;
    let codes = i32_input(&inputs[1], "codes")?;
    let cbs = f32_input(&inputs[2], "cbs")?;
    let v = f32_input(&inputs[3], "v")?;
    let mask = f32_input(&inputs[4], "mask")?;
    let mut out = vec![0.0f32; h * d_k];
    let mut scores = vec![0.0f32; l];
    let mut lut = vec![0.0f32; m * kk];
    for head in 0..h {
        let qh = &q[head * d_k..(head + 1) * d_k];
        let cb_h = &cbs[head * m * kk * d_sub..(head + 1) * m * kk * d_sub];
        build_lut_into(qh, cb_h, m, kk, d_sub, &mut lut);
        let codes_h = &codes[head * l * m..(head + 1) * l * m];
        for (t, s) in scores.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for i in 0..m {
                let c = codes_h[t * m + i];
                if c < 0 || c as usize >= kk {
                    bail!("{}: code {c} out of range K={kk}", spec.name);
                }
                acc += lut[i * kk + c as usize];
            }
            *s = acc;
        }
        masked_attention_tail(
            &scores,
            &v[head * l * d_k..(head + 1) * l * d_k],
            mask,
            d_k,
            &mut out[head * d_k..(head + 1) * d_k],
        );
    }
    Ok(out)
}

/// kind=lut_build — inputs (q[dk], cb[m,K,dsub]), output (m,K).
fn lut_build(
    spec: &ArtifactSpec,
    inputs: &[InputArg<'_>],
) -> anyhow::Result<Vec<f32>> {
    expect_arity(spec, "lut_build", 2)?;
    let bs = &spec.inputs[1].shape;
    if bs.len() != 3 {
        bail!("{}: unexpected lut_build shapes", spec.name);
    }
    let (m, kk, d_sub) = (bs[0], bs[1], bs[2]);
    if spec.inputs[0].elements() != m * d_sub {
        bail!("{}: q length != m*d_sub in manifest", spec.name);
    }
    let q = f32_input(&inputs[0], "q")?;
    let cb = f32_input(&inputs[1], "cb")?;
    let mut lut = vec![0.0f32; m * kk];
    build_lut_into(q, cb, m, kk, d_sub, &mut lut);
    Ok(lut)
}

/// kind=adc_scores — inputs (codes[L,m], lut[m,K]), output (L,).
fn adc_scores(
    spec: &ArtifactSpec,
    inputs: &[InputArg<'_>],
) -> anyhow::Result<Vec<f32>> {
    expect_arity(spec, "adc_scores", 2)?;
    let cs = &spec.inputs[0].shape;
    let ls = &spec.inputs[1].shape;
    if cs.len() != 2 || ls.len() != 2 {
        bail!("{}: unexpected adc_scores shapes", spec.name);
    }
    let (l, m) = (cs[0], cs[1]);
    let kk = ls[1];
    if ls[0] != m {
        bail!("{}: lut rows != codes' m in manifest", spec.name);
    }
    let codes = i32_input(&inputs[0], "codes")?;
    let lut = f32_input(&inputs[1], "lut")?;
    let mut out = vec![0.0f32; l];
    for (t, s) in out.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for i in 0..m {
            let c = codes[t * m + i];
            if c < 0 || c as usize >= kk {
                bail!("{}: code {c} out of range K={kk}", spec.name);
            }
            acc += lut[i * kk + c as usize];
        }
        *s = acc;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::{LookupTable, PqCodec, TrainOpts};
    use crate::util::rng::Pcg32;

    /// Build a Runtime over a synthetic in-memory manifest (no files on
    /// disk are needed because the interpreter never reads HLO text).
    fn runtime_with(manifest_json: &str) -> Runtime {
        let manifest =
            Manifest::parse(Path::new("/tmp"), manifest_json).unwrap();
        Runtime { manifest, loaded: HashSet::new() }
    }

    const LUT_MANIFEST: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "lut_build_m4", "file": "x.hlo.txt",
         "inputs": [
           {"name": "q", "shape": [32], "dtype": "float32"},
           {"name": "cb", "shape": [4, 16, 8], "dtype": "float32"}],
         "outputs": [{"name": "lut", "shape": [4, 16],
                      "dtype": "float32"}],
         "meta": {"kind": "lut_build", "m": 4}},
        {"name": "adc_scores_m4", "file": "x.hlo.txt",
         "inputs": [
           {"name": "codes", "shape": [64, 4], "dtype": "int32"},
           {"name": "lut", "shape": [4, 16], "dtype": "float32"}],
         "outputs": [{"name": "scores", "shape": [64],
                      "dtype": "float32"}],
         "meta": {"kind": "adc_scores", "m": 4}},
        {"name": "attn_fp16_L8", "file": "x.hlo.txt",
         "inputs": [
           {"name": "q", "shape": [2, 8], "dtype": "float32"},
           {"name": "k", "shape": [2, 8, 8], "dtype": "float32"},
           {"name": "v", "shape": [2, 8, 8], "dtype": "float32"},
           {"name": "mask", "shape": [8], "dtype": "float32"}],
         "outputs": [{"name": "out", "shape": [2, 8],
                      "dtype": "float32"}],
         "meta": {"kind": "attn_fp16", "L": 8}},
        {"name": "block_fp16_L8", "file": "x.hlo.txt",
         "inputs": [], "outputs": [],
         "meta": {"kind": "block_fp16", "L": 8}}
      ]}"#;

    #[test]
    fn lut_and_adc_match_hot_path() {
        let mut rt = runtime_with(LUT_MANIFEST);
        let (d_k, m, k, n) = (32usize, 4usize, 16usize, 64usize);
        let mut rng = Pcg32::seed(5);
        let calib: Vec<f32> =
            (0..256 * d_k).map(|_| rng.next_f32_std()).collect();
        let codec =
            PqCodec::train(&calib, d_k, m, k, &TrainOpts::default());
        let keys: Vec<f32> =
            (0..n * d_k).map(|_| rng.next_f32_std()).collect();
        let codes = codec.encode_batch(&keys, n);
        let q: Vec<f32> = (0..d_k).map(|_| rng.next_f32_std()).collect();
        let lut = LookupTable::build(&q, &codec.codebook);

        let cb_flat = codec.codebook.to_flat();
        let got_lut = rt
            .execute(
                "lut_build_m4",
                &[InputArg::F32(&q), InputArg::F32(&cb_flat)],
            )
            .unwrap();
        for (a, b) in got_lut[0].iter().zip(lut.as_slice()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }

        let codes_i32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
        let got_scores = rt
            .execute(
                "adc_scores_m4",
                &[InputArg::I32(&codes_i32), InputArg::F32(lut.as_slice())],
            )
            .unwrap();
        let want = lut.scores(&codes, n);
        for (a, b) in got_scores[0].iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn attn_fp16_matches_exact_attention_on_valid_prefix() {
        let mut rt = runtime_with(LUT_MANIFEST);
        let (h, d_k, l, valid) = (2usize, 8usize, 8usize, 5usize);
        let mut rng = Pcg32::seed(9);
        let q: Vec<f32> =
            (0..h * d_k).map(|_| rng.next_f32_std()).collect();
        let k: Vec<f32> =
            (0..h * l * d_k).map(|_| rng.next_f32_std()).collect();
        let v: Vec<f32> =
            (0..h * l * d_k).map(|_| rng.next_f32_std()).collect();
        let mask: Vec<f32> =
            (0..l).map(|i| if i < valid { 1.0 } else { 0.0 }).collect();
        let out = rt
            .execute(
                "attn_fp16_L8",
                &[
                    InputArg::F32(&q),
                    InputArg::F32(&k),
                    InputArg::F32(&v),
                    InputArg::F32(&mask),
                ],
            )
            .unwrap();
        for head in 0..h {
            let qh = &q[head * d_k..(head + 1) * d_k];
            let kh = &k[head * l * d_k..(head * l + valid) * d_k];
            let vh = &v[head * l * d_k..(head * l + valid) * d_k];
            let want = crate::attention::exact_attention(qh, kh, vh, valid);
            for (a, b) in
                out[0][head * d_k..(head + 1) * d_k].iter().zip(&want.out)
            {
                assert!((a - b).abs() < 1e-5, "head {head}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn unsupported_kind_and_unknown_artifact_error() {
        let mut rt = runtime_with(LUT_MANIFEST);
        let err = rt.execute("block_fp16_L8", &[]).unwrap_err().to_string();
        assert!(err.contains("not supported"), "{err}");
        assert!(rt.execute("no_such", &[]).is_err());
        assert_eq!(rt.platform(), "interp-cpu");
    }

    #[test]
    fn validation_errors_match_executor_contract() {
        let mut rt = runtime_with(LUT_MANIFEST);
        let q = vec![0.0f32; 3];
        let err = rt
            .execute("attn_fp16_L8", &[InputArg::F32(&q)])
            .unwrap_err()
            .to_string();
        assert!(err.contains("inputs"), "{err}");
    }
}
