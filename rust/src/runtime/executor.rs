//! The PJRT executor: CPU client + lazily-compiled executable registry.
//! Compiled only with `--features xla` (see `runtime/mod.rs`).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context};

use super::artifact::{ArtifactSpec, Manifest};
use super::{validate_inputs, InputArg};

fn to_literal(arg: &InputArg<'_>, shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    let lit = match arg {
        InputArg::F32(d) => xla::Literal::vec1(d),
        InputArg::I32(d) => xla::Literal::vec1(d),
    };
    Ok(lit.reshape(&dims)?)
}

/// PJRT runtime over one artifacts directory.
///
/// Executables compile lazily on first use and are cached for the process
/// lifetime — python is never involved (`make artifacts` already ran).
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open an artifacts directory (must contain manifest.json).
    pub fn open(dir: &Path) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client =
            xla::PjRtClient::cpu().context("PJRT CPU client init")?;
        crate::log_info!(
            "PJRT client up: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Runtime { client, manifest, executables: HashMap::new() })
    }

    /// Default artifacts directory (rust/artifacts), if built.
    pub fn open_default() -> anyhow::Result<Runtime> {
        Self::open(&super::default_artifacts_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Ensure an artifact is compiled; returns its spec.
    pub fn load(&mut self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        if !self.executables.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .with_context(|| format!("unknown artifact '{name}'"))?
                .clone();
            let path = self.manifest.dir.join(&spec.file);
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            crate::log_info!(
                "compiled {name} in {:.1} ms",
                t0.elapsed().as_secs_f64() * 1e3
            );
            self.executables.insert(name.to_string(), exe);
        }
        Ok(self.manifest.get(name).unwrap())
    }

    /// Execute an artifact with shape/dtype validation against the
    /// manifest. Returns one flat f32 vector per declared output.
    pub fn execute(
        &mut self,
        name: &str,
        inputs: &[InputArg<'_>],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        self.load(name)?;
        let spec = self.manifest.get(name).unwrap().clone();
        validate_inputs(&spec, inputs)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (arg, ispec) in inputs.iter().zip(&spec.inputs) {
            literals.push(to_literal(arg, &ispec.shape)?);
        }
        let exe = self.executables.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
        if tuple.len() != spec.outputs.len() {
            bail!(
                "{name}: graph returned {} outputs, manifest says {}",
                tuple.len(),
                spec.outputs.len()
            );
        }
        let mut outs = Vec::with_capacity(tuple.len());
        for (lit, ospec) in tuple.into_iter().zip(&spec.outputs) {
            let v: Vec<f32> = lit.to_vec()?;
            if v.len() != ospec.elements() {
                bail!(
                    "{name}.{}: output has {} elements, expected {}",
                    ospec.name,
                    v.len(),
                    ospec.elements()
                );
            }
            outs.push(v);
        }
        Ok(outs)
    }

    /// Names of artifacts compiled so far.
    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }
}
