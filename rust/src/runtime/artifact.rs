//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime. The manifest fully describes each lowered graph's
//! inputs/outputs, so the loader never guesses shapes.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json::Json;

/// Dtype + shape of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Option<TensorSpec> {
        Some(TensorSpec {
            name: j.get("name")?.as_str()?.to_string(),
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Option<Vec<_>>>()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-lowered graph.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactSpec {
    /// Integer meta field accessor (e.g. "L", "m", "H").
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key)?.as_usize()
    }

    pub fn kind(&self) -> &str {
        self.meta
            .get("kind")
            .and_then(|k| k.as_str())
            .unwrap_or("unknown")
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(text).context("manifest.json parse")?;
        let version = j
            .get("version")
            .and_then(|v| v.as_usize())
            .context("manifest version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let arts = j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest artifacts")?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let parse_specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(|x| x.as_arr())
                    .context("artifact specs")?
                    .iter()
                    .map(|t| {
                        TensorSpec::from_json(t).context("bad tensor spec")
                    })
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: a
                    .get("name")
                    .and_then(|n| n.as_str())
                    .context("artifact name")?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(|n| n.as_str())
                    .context("artifact file")?
                    .to_string(),
                inputs: parse_specs("inputs")?,
                outputs: parse_specs("outputs")?,
                meta: a.get("meta").cloned().unwrap_or(Json::obj()),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts of a given meta `kind`.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind() == kind).collect()
    }

    /// Find the attention artifact for (kind, L) — e.g. the decode-step
    /// graph for a padded cache length.
    pub fn attn_for(&self, kind: &str, l: usize, m: Option<usize>)
        -> Option<&ArtifactSpec>
    {
        self.artifacts.iter().find(|a| {
            a.kind() == kind
                && a.meta_usize("L") == Some(l)
                && (m.is_none() || a.meta_usize("m") == m)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "attn_fp16_L128", "file": "attn_fp16_L128.hlo.txt",
         "inputs": [
           {"name": "q", "shape": [12, 64], "dtype": "float32"},
           {"name": "k", "shape": [12, 128, 64], "dtype": "float32"}],
         "outputs": [{"name": "out", "shape": [12, 64],
                      "dtype": "float32"}],
         "meta": {"kind": "attn_fp16", "H": 12, "d_k": 64, "L": 128}},
        {"name": "attn_lookat_m4_L128", "file": "x.hlo.txt",
         "inputs": [{"name": "codes", "shape": [12, 128, 4],
                     "dtype": "int32"}],
         "outputs": [{"name": "out", "shape": [12, 64],
                      "dtype": "float32"}],
         "meta": {"kind": "attn_lookat", "L": 128, "m": 4}}
      ]}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("attn_fp16_L128").unwrap();
        assert_eq!(a.inputs[1].shape, vec![12, 128, 64]);
        assert_eq!(a.inputs[1].elements(), 12 * 128 * 64);
        assert_eq!(a.kind(), "attn_fp16");
        assert_eq!(a.meta_usize("L"), Some(128));
    }

    #[test]
    fn lookup_by_kind_and_shape() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.by_kind("attn_lookat").len(), 1);
        assert!(m.attn_for("attn_fp16", 128, None).is_some());
        assert!(m.attn_for("attn_fp16", 512, None).is_none());
        assert!(m.attn_for("attn_lookat", 128, Some(4)).is_some());
        assert!(m.attn_for("attn_lookat", 128, Some(8)).is_none());
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 99");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(
            Path::new("/tmp"),
            r#"{"version": 1, "artifacts": [{"name": "x"}]}"#
        )
        .is_err());
    }

    #[test]
    fn load_real_manifest_if_built() {
        // integration hook: validates against the real artifacts dir when
        // `make artifacts` has run (skips silently otherwise)
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 5);
            for a in &m.artifacts {
                assert!(dir.join(&a.file).exists(), "{} missing", a.file);
            }
        }
    }
}
