//! Artifact runtime: loads the AOT HLO-text artifacts produced by
//! `make artifacts` and executes them from the rust hot path.
//!
//! Two interchangeable backends behind one `Runtime` type:
//!
//! * **`--features xla`** — the PJRT CPU client (`executor`): compiles
//!   the HLO text through xla_extension and runs it on device. Interchange
//!   is HLO *text* — jax ≥0.5 serialized protos carry 64-bit instruction
//!   ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//! * **default** — a pure-rust interpreter (`interp`) over the manifest
//!   contract: it validates shapes/dtypes identically and evaluates the
//!   known artifact kinds (fp16 attention, LUT build, ADC scores, LOOKAT
//!   attention) with the same math as the L3 hot path. This keeps every
//!   `Pjrt*` code path compiling and testable in offline images where the
//!   `xla` crate is unavailable.
//!
//! Both backends share [`InputArg`], [`default_artifacts_dir`] and the
//! manifest-driven input validation, so error messages and calling
//! conventions are identical.

mod artifact;
#[cfg(feature = "xla")]
mod executor;
#[cfg(not(feature = "xla"))]
mod interp;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
#[cfg(feature = "xla")]
pub use executor::Runtime;
#[cfg(not(feature = "xla"))]
pub use interp::Runtime;

use std::path::Path;

use anyhow::bail;

/// Typed input argument for an artifact execution.
pub enum InputArg<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl InputArg<'_> {
    pub(crate) fn len(&self) -> usize {
        match self {
            InputArg::F32(d) => d.len(),
            InputArg::I32(d) => d.len(),
        }
    }

    pub(crate) fn dtype(&self) -> &'static str {
        match self {
            InputArg::F32(_) => "float32",
            InputArg::I32(_) => "int32",
        }
    }
}

/// Validate an input list against an artifact's manifest spec. Both the
/// PJRT executor and the interpreter call this, so shape/dtype errors
/// are identical across backends.
pub(crate) fn validate_inputs(
    spec: &ArtifactSpec,
    inputs: &[InputArg<'_>],
) -> anyhow::Result<()> {
    let name = &spec.name;
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
    }
    for (arg, ispec) in inputs.iter().zip(&spec.inputs) {
        if arg.len() != ispec.elements() {
            bail!(
                "{name}.{}: expected {} elements {:?}, got {}",
                ispec.name,
                ispec.elements(),
                ispec.shape,
                arg.len()
            );
        }
        if arg.dtype() != ispec.dtype {
            bail!(
                "{name}.{}: dtype {} != {}",
                ispec.name,
                arg.dtype(),
                ispec.dtype
            );
        }
    }
    Ok(())
}

/// `<repo>/rust/artifacts` resolved from the crate manifest dir.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            inputs: vec![
                TensorSpec {
                    name: "q".into(),
                    shape: vec![2, 3],
                    dtype: "float32".into(),
                },
                TensorSpec {
                    name: "codes".into(),
                    shape: vec![4],
                    dtype: "int32".into(),
                },
            ],
            outputs: vec![],
            meta: crate::util::json::Json::obj(),
        }
    }

    #[test]
    fn accepts_matching_inputs() {
        let q = [0.0f32; 6];
        let c = [0i32; 4];
        validate_inputs(&spec(), &[InputArg::F32(&q), InputArg::I32(&c)])
            .unwrap();
    }

    #[test]
    fn rejects_wrong_arity_count_and_dtype() {
        let q = [0.0f32; 6];
        let c = [0i32; 4];
        let short = [0.0f32; 5];
        let e = validate_inputs(&spec(), &[InputArg::F32(&q)])
            .unwrap_err()
            .to_string();
        assert!(e.contains("inputs"), "{e}");
        let e2 = validate_inputs(
            &spec(),
            &[InputArg::F32(&short), InputArg::I32(&c)],
        )
        .unwrap_err()
        .to_string();
        assert!(e2.contains("elements"), "{e2}");
        let wrong_ty = [0i32; 6];
        let e3 = validate_inputs(
            &spec(),
            &[InputArg::I32(&wrong_ty), InputArg::I32(&c)],
        )
        .unwrap_err()
        .to_string();
        assert!(e3.contains("dtype"), "{e3}");
    }
}
