//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `make artifacts` and executes them from the rust hot path.
//!
//! Interchange is HLO *text* — jax ≥0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md).

mod artifact;
mod executor;

pub use artifact::{ArtifactSpec, Manifest, TensorSpec};
pub use executor::{default_artifacts_dir, InputArg, Runtime};
