//! Per-request event tracer: a fixed ring of atomic slots recording
//! scheduler/engine span events, dumpable as Chrome `trace_event` JSON
//! (load the file in Perfetto or `chrome://tracing`).
//!
//! Recording writes four relaxed `AtomicU64` stores plus one
//! `fetch_add` on the head — no locks, no allocation — so the tracer
//! can stay attached on the decode hot path. The ring overwrites the
//! oldest events once full; `dump_chrome_json` emits whatever is still
//! resident, sorted by timestamp.

use std::sync::atomic::{AtomicU64, Ordering};

/// What happened. Stored in the low 32 bits of the packed word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum TraceKind {
    /// Request entered the queue (arg = prompt tokens).
    Queued = 0,
    /// Scheduler admitted it (arg = tokens reused from the prefix cache).
    Admitted = 1,
    /// One chunked-prefill span ran (arg = chunk tokens; duration = tick).
    PrefillChunk = 2,
    /// One decode step ran (arg = batch occupancy; duration = tick).
    DecodeTick = 3,
    /// Victim preempted with its blocks freed (arg = blocks released).
    Preempt = 4,
    /// Victim preempted to the swap tier (arg = bytes written out).
    SwapOut = 5,
    /// Swapped sequence restored (arg = blocks re-allocated).
    SwapIn = 6,
    /// Request finished (arg = generated tokens).
    Finish = 7,
    /// Request rejected by admission control (arg = prompt tokens).
    Rejected = 8,
}

impl TraceKind {
    fn from_u32(v: u32) -> Option<TraceKind> {
        match v {
            0 => Some(TraceKind::Queued),
            1 => Some(TraceKind::Admitted),
            2 => Some(TraceKind::PrefillChunk),
            3 => Some(TraceKind::DecodeTick),
            4 => Some(TraceKind::Preempt),
            5 => Some(TraceKind::SwapOut),
            6 => Some(TraceKind::SwapIn),
            7 => Some(TraceKind::Finish),
            8 => Some(TraceKind::Rejected),
            _ => None,
        }
    }

    fn name(self) -> &'static str {
        match self {
            TraceKind::Queued => "queued",
            TraceKind::Admitted => "admitted",
            TraceKind::PrefillChunk => "prefill_chunk",
            TraceKind::DecodeTick => "decode_tick",
            TraceKind::Preempt => "preempt",
            TraceKind::SwapOut => "swap_out",
            TraceKind::SwapIn => "swap_in",
            TraceKind::Finish => "finish",
            TraceKind::Rejected => "rejected",
        }
    }

    /// Span events render as Chrome "X" (complete) events with a
    /// duration; the rest are "i" (instant) marks.
    fn is_span(self) -> bool {
        matches!(self, TraceKind::PrefillChunk | TraceKind::DecodeTick)
    }
}

/// One recorded event, unpacked.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub ts_us: u64,
    pub seq: u64,
    pub dur_us: u64,
    pub kind: TraceKind,
    pub arg: u32,
}

const WORDS: usize = 4;

/// Lock-free single-writer ring. All serving events are recorded from
/// the serving-loop thread, so slots cannot interleave; readers only
/// run `dump` from that same thread (the `trace-dump` verb is answered
/// by the serve loop).
pub struct TraceRing {
    head: AtomicU64,
    /// `capacity * WORDS` atomics: ts_us, seq, dur_us, kind|arg<<32.
    slots: Box<[AtomicU64]>,
    capacity: usize,
}

impl TraceRing {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(16);
        TraceRing {
            head: AtomicU64::new(0),
            slots: (0..capacity * WORDS).map(|_| AtomicU64::new(0)).collect(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Total events ever recorded (>= resident count once wrapped).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Record an event. `ts_s` is seconds on the serving clock; seq is
    /// the request id (0 for engine-wide events). `arg` is clamped to
    /// 31 bits — bit 63 of the packed word is the VALID flag.
    pub fn record(&self, ts_s: f64, seq: u64, kind: TraceKind, dur_s: f64, arg: u32) {
        let i = self.head.fetch_add(1, Ordering::Relaxed) as usize % self.capacity;
        let base = i * WORDS;
        let ts_us = (ts_s.max(0.0) * 1e6) as u64;
        let dur_us = (dur_s.max(0.0) * 1e6) as u64;
        let arg = arg.min(0x7fff_ffff);
        self.slots[base].store(ts_us, Ordering::Relaxed);
        self.slots[base + 1].store(seq, Ordering::Relaxed);
        self.slots[base + 2].store(dur_us, Ordering::Relaxed);
        self.slots[base + 3]
            .store(kind as u32 as u64 | ((arg as u64) << 32), Ordering::Relaxed);
        // Publish: mark the slot initialized only after its words are
        // written, so a racing dump skips half-written slots.
        self.slots[base + 3].fetch_or(VALID, Ordering::Release);
    }

    /// Resident events, oldest first.
    pub fn events(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.capacity);
        for i in 0..self.capacity {
            let base = i * WORDS;
            let packed = self.slots[base + 3].load(Ordering::Acquire);
            if packed & VALID == 0 {
                continue;
            }
            let packed = packed & !VALID;
            let Some(kind) = TraceKind::from_u32((packed & 0xffff_ffff) as u32)
            else {
                continue;
            };
            out.push(TraceEvent {
                ts_us: self.slots[base].load(Ordering::Relaxed),
                seq: self.slots[base + 1].load(Ordering::Relaxed),
                dur_us: self.slots[base + 2].load(Ordering::Relaxed),
                kind,
                arg: (packed >> 32) as u32,
            });
        }
        out.sort_by_key(|e| e.ts_us);
        out
    }

    /// Render the resident events as a Chrome `trace_event` JSON array
    /// (the format Perfetto and chrome://tracing open directly). Each
    /// request gets its own `tid` lane; engine-wide events (seq 0 ticks)
    /// land on lane 0.
    pub fn dump_chrome_json(&self) -> String {
        let events = self.events();
        let mut out = String::from("[\n");
        for (n, e) in events.iter().enumerate() {
            let (ph, dur) = if e.kind.is_span() {
                ("X", format!(",\"dur\":{}", e.dur_us.max(1)))
            } else {
                ("i", String::new())
            };
            let scope = if e.kind.is_span() { "" } else { ",\"s\":\"t\"" };
            out.push_str(&format!(
                "{{\"name\":\"{}\",\"ph\":\"{ph}\",\"ts\":{},\"pid\":1,\
                 \"tid\":{}{dur}{scope},\"args\":{{\"v\":{}}}}}",
                e.kind.name(),
                e.ts_us,
                e.seq,
                e.arg
            ));
            out.push_str(if n + 1 == events.len() { "\n" } else { ",\n" });
        }
        out.push_str("]\n");
        out
    }
}

impl std::fmt::Debug for TraceRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceRing")
            .field("capacity", &self.capacity)
            .field("recorded", &self.recorded())
            .finish()
    }
}

/// High bit of the packed kind word marks an initialized slot.
const VALID: u64 = 1 << 63;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn records_and_reads_back_in_order() {
        let ring = TraceRing::new(64);
        ring.record(0.001, 7, TraceKind::Queued, 0.0, 128);
        ring.record(0.002, 7, TraceKind::Admitted, 0.0, 0);
        ring.record(0.003, 7, TraceKind::DecodeTick, 0.0005, 4);
        ring.record(0.004, 7, TraceKind::Finish, 0.0, 16);
        let ev = ring.events();
        assert_eq!(ev.len(), 4);
        assert_eq!(ev[0].kind, TraceKind::Queued);
        assert_eq!(ev[0].arg, 128);
        assert_eq!(ev[2].dur_us, 500);
        assert_eq!(ev[3].kind, TraceKind::Finish);
        assert!(ev.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
    }

    #[test]
    fn ring_wraps_keeping_the_newest_events() {
        let ring = TraceRing::new(16);
        for i in 0..100u64 {
            ring.record(i as f64 * 1e-3, i, TraceKind::DecodeTick, 1e-4, 1);
        }
        assert_eq!(ring.recorded(), 100);
        let ev = ring.events();
        assert_eq!(ev.len(), 16);
        // Only the most recent 16 survive.
        assert!(ev.iter().all(|e| e.seq >= 84));
    }

    #[test]
    fn chrome_json_parses_and_has_span_durations() {
        let ring = TraceRing::new(32);
        ring.record(0.010, 1, TraceKind::Queued, 0.0, 64);
        ring.record(0.020, 1, TraceKind::PrefillChunk, 0.004, 64);
        ring.record(0.025, 1, TraceKind::SwapOut, 0.0, 4096);
        ring.record(0.030, 1, TraceKind::DecodeTick, 0.002, 2);
        let text = ring.dump_chrome_json();
        let j = Json::parse(&text).expect("valid json");
        let arr = j.as_arr().expect("array");
        assert_eq!(arr.len(), 4);
        let prefill = arr
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("prefill_chunk"))
            .unwrap();
        assert_eq!(prefill.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(prefill.get("dur").and_then(Json::as_f64), Some(4000.0));
        assert_eq!(prefill.get("tid").and_then(Json::as_f64), Some(1.0));
        let swap = arr
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("swap_out"))
            .unwrap();
        assert_eq!(swap.get("ph").and_then(Json::as_str), Some("i"));
        assert_eq!(
            swap.get("args").and_then(|a| a.get("v")).and_then(Json::as_f64),
            Some(4096.0)
        );
    }

    #[test]
    fn empty_ring_dumps_an_empty_array() {
        let ring = TraceRing::new(16);
        let j = Json::parse(&ring.dump_chrome_json()).unwrap();
        assert_eq!(j.as_arr().unwrap().len(), 0);
    }
}
