//! Lock-free metrics registry for the serving stack.
//!
//! One `MetricsRegistry` lives inside each `Engine`; the batcher,
//! router and TCP server reach it through `Engine::metrics()`. Every
//! instrument is enum-indexed into a fixed atomic array, so publishing
//! is a relaxed `fetch_add`/`store` with no locks, no hashing and no
//! allocation — cheap enough to run unconditionally on the decode hot
//! path. Snapshots are plain data: mergeable across registries and
//! renderable as JSON (the `{"cmd":"stats"}` verb) or Prometheus text
//! exposition (the `--metrics-addr` endpoint).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

use super::histogram::{HistogramSnapshot, LogHistogram};

macro_rules! metric_enum {
    ($(#[$meta:meta])* $name:ident { $($variant:ident => $label:literal),+ $(,)? }) => {
        $(#[$meta])*
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        #[repr(usize)]
        pub enum $name {
            $($variant),+
        }

        impl $name {
            pub const ALL: &'static [$name] = &[$($name::$variant),+];

            pub fn name(self) -> &'static str {
                match self {
                    $($name::$variant => $label),+
                }
            }
        }
    };
}

metric_enum!(
    /// Monotonic counters (cumulative since engine construction).
    Ctr {
        RequestsSubmitted => "requests_submitted",
        RequestsCompleted => "requests_completed",
        RequestsRejected => "requests_rejected",
        Preemptions => "preemptions",
        SwapOuts => "swap_outs",
        SwapIns => "swap_ins",
        SwapBytesOut => "swap_bytes_out",
        SwapBytesIn => "swap_bytes_in",
        PrefixHits => "prefix_hits",
        PrefixTokensReused => "prefix_tokens_reused",
        DecodeTokens => "decode_tokens",
        PrefillTokens => "prefill_tokens",
        Ticks => "ticks",
        ScanBytes => "scan_bytes",
        PrunedTokens => "pruned_tokens",
        PhaseLutBuildNs => "phase_lut_build_ns",
        PhaseScanNs => "phase_scan_ns",
        PhaseValueDecodeNs => "phase_value_decode_ns",
        PhaseQkvNs => "phase_qkv_ns",
        PhaseMlpNs => "phase_mlp_ns",
        FaultsInjected => "faults_injected",
        DeadlineExpired => "deadline_expired",
        PanicsQuarantined => "panics_quarantined",
        ChecksumFailures => "checksum_failures",
    }
);

metric_enum!(
    /// Point-in-time gauges, re-sampled once per scheduler tick.
    Gauge {
        QueueDepth => "queue_depth",
        ActiveSeqs => "active_seqs",
        SwappedSeqs => "swapped_seqs",
        BlocksFree => "blocks_free",
        BlocksUsed => "blocks_used",
        BlocksTotal => "blocks_total",
        SharedBlocks => "shared_blocks",
        KeyCacheBytes => "key_cache_bytes",
        ValueCacheBytes => "value_cache_bytes",
        SwapResidentBytes => "swap_resident_bytes",
        ScratchLeases => "scratch_leases",
        ScratchFresh => "scratch_fresh",
        ScratchZeroed => "scratch_zeroed",
        ScratchHeldBytes => "scratch_held_bytes",
        ScratchPeakBytes => "scratch_peak_bytes",
        DrainDurationMs => "drain_duration_ms",
    }
);

metric_enum!(
    /// Histograms. Latency instruments record seconds into log-spaced
    /// buckets; `BatchOccupancy` records sequences per tick.
    Hist {
        TtftS => "ttft_s",
        ItlS => "itl_s",
        E2eS => "e2e_s",
        TickS => "tick_s",
        BatchOccupancy => "batch_occupancy",
    }
);

impl Hist {
    fn make(self) -> LogHistogram {
        match self {
            Hist::BatchOccupancy => LogHistogram::occupancy(),
            _ => LogHistogram::latency(),
        }
    }
}

pub struct MetricsRegistry {
    counters: Box<[AtomicU64]>,
    gauges: Box<[AtomicU64]>,
    hists: Box<[LogHistogram]>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry {
            counters: (0..Ctr::ALL.len()).map(|_| AtomicU64::new(0)).collect(),
            gauges: (0..Gauge::ALL.len()).map(|_| AtomicU64::new(0)).collect(),
            hists: Hist::ALL.iter().map(|h| h.make()).collect(),
        }
    }

    #[inline]
    pub fn inc(&self, c: Ctr, by: u64) {
        self.counters[c as usize].fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, c: Ctr) -> u64 {
        self.counters[c as usize].load(Ordering::Relaxed)
    }

    #[inline]
    pub fn set(&self, g: Gauge, v: u64) {
        self.gauges[g as usize].store(v, Ordering::Relaxed);
    }

    pub fn gauge(&self, g: Gauge) -> u64 {
        self.gauges[g as usize].load(Ordering::Relaxed)
    }

    #[inline]
    pub fn observe(&self, h: Hist, x: f64) {
        self.hists[h as usize].observe(x);
    }

    pub fn hist(&self, h: Hist) -> &LogHistogram {
        &self.hists[h as usize]
    }

    /// Drain one histogram (snapshot + reset). Used by per-run report
    /// builders; the counters and gauges stay cumulative.
    pub fn take_hist(&self, h: Hist) -> HistogramSnapshot {
        self.hists[h as usize].take()
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: Ctr::ALL.iter().map(|&c| (c.name(), self.counter(c))).collect(),
            gauges: Gauge::ALL.iter().map(|&g| (g.name(), self.gauge(g))).collect(),
            hists: Hist::ALL
                .iter()
                .map(|&h| (h.name(), self.hists[h as usize].snapshot()))
                .collect(),
        }
    }
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MetricsRegistry")
            .field("counters", &Ctr::ALL.len())
            .field("gauges", &Gauge::ALL.len())
            .field("hists", &Hist::ALL.len())
            .finish()
    }
}

/// Plain-data copy of the whole registry, renderable and mergeable.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub hists: Vec<(&'static str, HistogramSnapshot)>,
}

impl MetricsSnapshot {
    /// Combine a peer snapshot (e.g. another shard): counters and
    /// histogram buckets add; gauges add too, since each shard's gauge
    /// describes disjoint resources (its own blocks, queue, arenas).
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for ((_, a), (_, b)) in self.counters.iter_mut().zip(&other.counters) {
            *a += b;
        }
        for ((_, a), (_, b)) in self.gauges.iter_mut().zip(&other.gauges) {
            *a += b;
        }
        for ((_, a), (_, b)) in self.hists.iter_mut().zip(&other.hists) {
            a.merge(b);
        }
    }

    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (name, v) in &self.counters {
            counters.set(name, Json::Num(*v as f64));
        }
        let mut gauges = Json::obj();
        for (name, v) in &self.gauges {
            gauges.set(name, Json::Num(*v as f64));
        }
        let mut hists = Json::obj();
        for (name, snap) in &self.hists {
            let mut h = Json::obj();
            h.set("count", Json::Num(snap.count as f64));
            h.set("sum", Json::Num(snap.sum));
            if let (Some(p50), Some(p90), Some(p99)) =
                (snap.p50(), snap.p90(), snap.p99())
            {
                h.set("p50", Json::Num(p50));
                h.set("p90", Json::Num(p90));
                h.set("p99", Json::Num(p99));
            }
            hists.set(name, h);
        }
        let mut out = Json::obj();
        out.set("counters", counters);
        out.set("gauges", gauges);
        out.set("histograms", hists);
        out
    }

    /// Prometheus text exposition (version 0.0.4): counters, gauges,
    /// and cumulative-`le` histogram series under a `lookat_` prefix.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!(
                "# TYPE lookat_{name} counter\nlookat_{name} {v}\n"
            ));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!(
                "# TYPE lookat_{name} gauge\nlookat_{name} {v}\n"
            ));
        }
        for (name, snap) in &self.hists {
            out.push_str(&format!("# TYPE lookat_{name} histogram\n"));
            let mut cum = 0u64;
            for (i, &c) in snap.buckets.iter().enumerate() {
                cum += c;
                out.push_str(&format!(
                    "lookat_{name}_bucket{{le=\"{:.6e}\"}} {cum}\n",
                    snap.bucket_hi(i)
                ));
            }
            out.push_str(&format!(
                "lookat_{name}_bucket{{le=\"+Inf\"}} {}\n",
                snap.count
            ));
            out.push_str(&format!("lookat_{name}_sum {}\n", snap.sum));
            out.push_str(&format!("lookat_{name}_count {}\n", snap.count));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counters_and_gauges_round_trip() {
        let r = MetricsRegistry::new();
        r.inc(Ctr::DecodeTokens, 5);
        r.inc(Ctr::DecodeTokens, 3);
        r.set(Gauge::QueueDepth, 7);
        r.set(Gauge::QueueDepth, 2);
        assert_eq!(r.counter(Ctr::DecodeTokens), 8);
        assert_eq!(r.gauge(Gauge::QueueDepth), 2);
        assert_eq!(r.counter(Ctr::Preemptions), 0);
    }

    #[test]
    fn snapshot_json_has_every_instrument() {
        let r = MetricsRegistry::new();
        r.inc(Ctr::Ticks, 1);
        r.observe(Hist::TickS, 0.01);
        let j = r.snapshot().to_json();
        for c in Ctr::ALL {
            assert!(
                j.get("counters").and_then(|o| o.get(c.name())).is_some(),
                "missing counter {}",
                c.name()
            );
        }
        for g in Gauge::ALL {
            assert!(
                j.get("gauges").and_then(|o| o.get(g.name())).is_some(),
                "missing gauge {}",
                g.name()
            );
        }
        for h in Hist::ALL {
            assert!(
                j.get("histograms").and_then(|o| o.get(h.name())).is_some(),
                "missing histogram {}",
                h.name()
            );
        }
        // Non-empty histograms expose percentiles; empty ones omit them.
        let tick = j.get("histograms").unwrap().get("tick_s").unwrap();
        assert!(tick.get("p50").is_some());
        let ttft = j.get("histograms").unwrap().get("ttft_s").unwrap();
        assert!(ttft.get("p50").is_none());
        assert_eq!(ttft.get("count").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let r = MetricsRegistry::new();
        r.inc(Ctr::ScanBytes, 1 << 20);
        r.set(Gauge::BlocksFree, 42);
        for i in 1..=100 {
            r.observe(Hist::TtftS, i as f64 * 1e-3);
        }
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE lookat_scan_bytes counter"));
        assert!(text.contains("lookat_scan_bytes 1048576"));
        assert!(text.contains("lookat_blocks_free 42"));
        assert!(text.contains("# TYPE lookat_ttft_s histogram"));
        assert!(text.contains("lookat_ttft_s_bucket{le=\"+Inf\"} 100"));
        assert!(text.contains("lookat_ttft_s_count 100"));
        // `le` bounds must be cumulative and end at the total count.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("lookat_ttft_s_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-cumulative bucket line: {line}");
            last = v;
        }
        assert_eq!(last, 100);
    }

    #[test]
    fn snapshot_merge_adds_everything() {
        let a = MetricsRegistry::new();
        let b = MetricsRegistry::new();
        a.inc(Ctr::DecodeTokens, 10);
        b.inc(Ctr::DecodeTokens, 32);
        a.set(Gauge::BlocksUsed, 4);
        b.set(Gauge::BlocksUsed, 6);
        a.observe(Hist::ItlS, 0.002);
        b.observe(Hist::ItlS, 0.004);
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        let decode = m.counters.iter().find(|(n, _)| *n == "decode_tokens").unwrap();
        assert_eq!(decode.1, 42);
        let used = m.gauges.iter().find(|(n, _)| *n == "blocks_used").unwrap();
        assert_eq!(used.1, 10);
        let itl = &m.hists.iter().find(|(n, _)| *n == "itl_s").unwrap().1;
        assert_eq!(itl.count, 2);
    }

    #[test]
    fn registry_is_shareable_across_threads() {
        let r = Arc::new(MetricsRegistry::new());
        let mut joins = Vec::new();
        for _ in 0..8 {
            let r = Arc::clone(&r);
            joins.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    r.inc(Ctr::DecodeTokens, 1);
                    r.observe(Hist::ItlS, 1e-3);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(r.counter(Ctr::DecodeTokens), 80_000);
        assert_eq!(r.hist(Hist::ItlS).count(), 80_000);
    }
}
