//! Serving telemetry: live metrics registry, latency histograms, and a
//! per-request event tracer.
//!
//! The paper's thesis is that LOOKAT turns attention from memory-bound
//! to compute-bound; this module is how a *live* serving process proves
//! it. The [`MetricsRegistry`] is published into by the batcher (queue
//! depth, occupancy, TTFT/ITL/tick histograms), the engine (token
//! counters, ADC scan bytes, per-phase timer deltas, cache/swap/arena
//! gauges, pruned-token counts under a pruning compression policy),
//! and is drained per run into `ServingReport` or served live
//! via the `{"cmd":"stats"}` verb and the `--metrics-addr` Prometheus
//! endpoint. The [`TraceRing`] records per-request span events as
//! Chrome `trace_event` JSON for Perfetto.
//!
//! Everything here is observability-only and lock-free on the hot
//! path: relaxed atomics, fixed preallocated buffers, no allocation per
//! event. Note this is distinct from `crate::metrics`, which holds the
//! paper-fidelity *quality* metrics (cosine error, KL, overlap).

pub mod histogram;
pub mod registry;
pub mod trace;

pub use histogram::{HistogramSnapshot, LogHistogram};
pub use registry::{Ctr, Gauge, Hist, MetricsRegistry, MetricsSnapshot};
pub use trace::{TraceEvent, TraceKind, TraceRing};
