//! Lock-free log-spaced histograms for latency and occupancy metrics.
//!
//! `LogHistogram` is a fixed array of atomic bucket counters with
//! geometrically spaced bounds: bucket `i` covers `[lo·r^i, lo·r^(i+1))`
//! (bucket 0 additionally absorbs everything below `lo`, the last bucket
//! everything above the top bound). Recording is a single relaxed
//! `fetch_add` — no locks, no allocation — so the serving hot path can
//! observe per-tick and per-token latencies for free.
//!
//! Snapshots are plain `u64` vectors that can be merged across
//! registries (same geometry required) and queried for quantiles: the
//! extracted percentile is the geometric midpoint of the bucket holding
//! the rank-th smallest sample, i.e. always within one bucket width of
//! the exact order statistic.

use std::sync::atomic::{AtomicU64, Ordering};

/// Fixed-point scale used to accumulate the running sum atomically:
/// micro-units (µs for seconds-valued histograms).
const SUM_SCALE: f64 = 1e6;

pub struct LogHistogram {
    lo: f64,
    ratio: f64,
    /// Cached 1/ln(ratio) so bucket indexing is one ln + one multiply.
    inv_ln_ratio: f64,
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    /// Sum of observed values in fixed-point micro-units.
    sum_micros: AtomicU64,
}

impl LogHistogram {
    /// `n` buckets spanning `[lo, lo·ratio^n)`; out-of-range samples
    /// clamp into the first/last bucket.
    pub fn new(lo: f64, ratio: f64, n: usize) -> Self {
        assert!(lo > 0.0 && ratio > 1.0 && n > 0, "bad histogram geometry");
        let buckets = (0..n).map(|_| AtomicU64::new(0)).collect();
        LogHistogram {
            lo,
            ratio,
            inv_ln_ratio: 1.0 / ratio.ln(),
            buckets,
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
        }
    }

    /// Geometry used for latency metrics (seconds): 64 √2-spaced buckets
    /// from 1µs, topping out around 4300s — decode ticks, TTFT and
    /// end-to-end latencies all land well inside.
    pub fn latency() -> Self {
        LogHistogram::new(1e-6, std::f64::consts::SQRT_2, 64)
    }

    /// Geometry for small-integer distributions (batch occupancy, queue
    /// depth): 32 √2-spaced buckets from 1, topping out at 65536.
    pub fn occupancy() -> Self {
        LogHistogram::new(1.0, std::f64::consts::SQRT_2, 32)
    }

    fn bucket_index(&self, x: f64) -> usize {
        // NaN fails the comparison and lands in bucket 0; +inf saturates
        // through the float-to-int cast into the last bucket.
        if !(x > self.lo) {
            return 0;
        }
        let n = self.buckets.len();
        let mut i =
            (((x / self.lo).ln() * self.inv_ln_ratio) as usize).min(n - 1);
        // ln() rounding can land an exact boundary one bucket off (e.g.
        // ln(128)/ln(2) = 6.999…); nudge against the true geometric
        // bounds so `[lo·r^i, lo·r^(i+1))` holds exactly.
        if i + 1 < n && x >= self.lo * self.ratio.powi(i as i32 + 1) {
            i += 1;
        } else if x < self.lo * self.ratio.powi(i as i32) {
            i -= 1;
        }
        i
    }

    /// Record one sample. Relaxed atomics only; safe from any thread.
    pub fn observe(&self, x: f64) {
        let i = self.bucket_index(x);
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        if x.is_finite() && x > 0.0 {
            let fp = (x * SUM_SCALE) as u64;
            self.sum_micros.fetch_add(fp, Ordering::Relaxed);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the current state out without disturbing it.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            lo: self.lo,
            ratio: self.ratio,
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum_micros.load(Ordering::Relaxed) as f64 / SUM_SCALE,
        }
    }

    /// Drain: snapshot then reset, so per-run consumers (ServingReport)
    /// see only their own interval while the live registry stays
    /// cumulative for anyone polling `stats`.
    pub fn take(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.swap(0, Ordering::Relaxed))
            .collect();
        let drained: u64 = buckets.iter().sum();
        // `count` may transiently disagree with the bucket sum if an
        // observe() races the drain; derive count from what we actually
        // took and subtract it, so nothing is double-counted or lost.
        self.count.fetch_sub(drained, Ordering::Relaxed);
        let sum = self.sum_micros.swap(0, Ordering::Relaxed) as f64 / SUM_SCALE;
        HistogramSnapshot {
            lo: self.lo,
            ratio: self.ratio,
            buckets,
            count: drained,
            sum,
        }
    }
}

impl std::fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LogHistogram")
            .field("lo", &self.lo)
            .field("ratio", &self.ratio)
            .field("n", &self.buckets.len())
            .field("count", &self.count())
            .finish()
    }
}

/// Point-in-time copy of a `LogHistogram`: plain data, mergeable,
/// queryable for percentiles.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    pub lo: f64,
    pub ratio: f64,
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl HistogramSnapshot {
    /// Empty snapshot with latency geometry (for default reports).
    pub fn empty_latency() -> Self {
        LogHistogram::latency().snapshot()
    }

    pub fn bucket_lo(&self, i: usize) -> f64 {
        self.lo * self.ratio.powi(i as i32)
    }

    pub fn bucket_hi(&self, i: usize) -> f64 {
        self.lo * self.ratio.powi(i as i32 + 1)
    }

    /// Merge another snapshot in (same geometry required). Counts and
    /// sums add; this is the shard-combining primitive.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        assert!(
            self.buckets.len() == other.buckets.len()
                && (self.lo - other.lo).abs() < 1e-12
                && (self.ratio - other.ratio).abs() < 1e-12,
            "cannot merge histograms with different geometry"
        );
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Quantile in [0,1]. Returns the geometric midpoint of the bucket
    /// containing the `ceil(q·count)`-th smallest sample, or `None` when
    /// empty — callers use that to render `n/a` / omit JSON keys.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(self.bucket_lo(i) * self.ratio.sqrt());
            }
        }
        // Unreachable when counts are consistent; clamp to the top.
        Some(self.bucket_hi(self.buckets.len() - 1))
    }

    pub fn p50(&self) -> Option<f64> {
        self.percentile(0.50)
    }

    pub fn p90(&self) -> Option<f64> {
        self.percentile(0.90)
    }

    pub fn p99(&self) -> Option<f64> {
        self.percentile(0.99)
    }

    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.sum / self.count as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::stats::percentile_sorted;
    use std::sync::Arc;

    #[test]
    fn bucket_boundaries_land_in_expected_buckets() {
        let h = LogHistogram::new(1.0, 2.0, 8);
        // Exactly on a boundary belongs to the bucket it opens; just
        // below stays in the previous one.
        for (x, want) in [
            (0.5, 0),   // below lo clamps to bucket 0
            (1.0, 0),   // lo itself
            (1.99, 0),  // just under the first boundary
            (2.0, 1),   // boundary opens bucket 1
            (4.0, 2),
            (127.9, 6),
            (128.0, 7),
            (1e9, 7),   // above the top clamps to the last bucket
        ] {
            h.observe(x);
            let snap = h.snapshot();
            let hot: Vec<usize> = snap
                .buckets
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(i, _)| i)
                .collect();
            assert!(
                hot.contains(&want),
                "x={x} expected bucket {want}, hot buckets {hot:?}"
            );
            // Drain between probes so each sample is checked alone.
            h.take();
        }
    }

    #[test]
    fn boundary_indexing_is_monotone_across_the_range() {
        let h = LogHistogram::latency();
        let mut last = 0usize;
        let mut x = 1e-7;
        while x < 1e4 {
            let i = h.bucket_index(x);
            assert!(i >= last, "bucket index regressed at x={x}");
            last = i;
            x *= 1.11;
        }
        assert_eq!(h.bucket_index(f64::NAN), 0);
        assert_eq!(h.bucket_index(f64::INFINITY), 63);
    }

    #[test]
    fn merge_equals_observing_everything_in_one_histogram() {
        let mut rng = Pcg32::seed(0x7e1e_0001);
        let a = LogHistogram::latency();
        let b = LogHistogram::latency();
        let all = LogHistogram::latency();
        for i in 0..4000 {
            let x = 10f64.powf(rng.next_f64() * 6.0 - 5.5); // 3e-6 .. 3e0
            if i % 2 == 0 {
                a.observe(x);
            } else {
                b.observe(x);
            }
            all.observe(x);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let whole = all.snapshot();
        assert_eq!(merged.buckets, whole.buckets);
        assert_eq!(merged.count, whole.count);
        assert!((merged.sum - whole.sum).abs() < 1e-6 * whole.sum.max(1.0));
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(merged.percentile(q), whole.percentile(q));
        }
    }

    #[test]
    #[should_panic(expected = "different geometry")]
    fn merge_rejects_mismatched_geometry() {
        let mut a = LogHistogram::latency().snapshot();
        let b = LogHistogram::occupancy().snapshot();
        a.merge(&b);
    }

    #[test]
    fn percentiles_match_sorted_vec_oracle_within_one_bucket() {
        let mut rng = Pcg32::seed(0x7e1e_0002);
        let h = LogHistogram::latency();
        let mut samples: Vec<f64> = Vec::new();
        for _ in 0..5000 {
            // Mixture: a log-uniform body plus a heavy tail, so the
            // quantiles cross many buckets.
            let base = 10f64.powf(rng.next_f64() * 3.0 - 4.0); // 1e-4..1e-1
            let x = if rng.next_f64() < 0.05 { base * 50.0 } else { base };
            h.observe(x);
            samples.push(x);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let snap = h.snapshot();
        for q in [0.5, 0.9, 0.99] {
            // Oracle order statistic under the same rank rule the
            // histogram uses; the histogram must land in its bucket.
            let rank = ((q * samples.len() as f64).ceil() as usize)
                .clamp(1, samples.len());
            let exact = samples[rank - 1];
            let got = snap.percentile(q).unwrap();
            let i = snap
                .buckets
                .iter()
                .scan(0u64, |acc, &c| {
                    *acc += c;
                    Some(*acc)
                })
                .position(|c| c >= rank as u64)
                .unwrap();
            assert!(
                exact >= snap.bucket_lo(i) * 0.999
                    && exact <= snap.bucket_hi(i) * 1.001,
                "q={q}: oracle {exact} outside bucket [{}, {})",
                snap.bucket_lo(i),
                snap.bucket_hi(i)
            );
            let width = snap.bucket_hi(i) - snap.bucket_lo(i);
            assert!(
                (got - exact).abs() <= width,
                "q={q}: hist {got} vs oracle {exact}, bucket width {width}"
            );
            // And the interpolating library percentile stays within a
            // neighboring bucket of the histogram estimate.
            let interp = percentile_sorted(&samples, q);
            assert!(
                interp >= snap.bucket_lo(i.saturating_sub(1))
                    && interp <= snap.bucket_hi((i + 1).min(snap.buckets.len() - 1)),
                "q={q}: interpolated oracle {interp} more than one bucket away"
            );
        }
    }

    #[test]
    fn multi_thread_hammer_loses_nothing() {
        let h = Arc::new(LogHistogram::latency());
        let threads = 8;
        let per = 20_000u64;
        let mut joins = Vec::new();
        for t in 0..threads {
            let h = Arc::clone(&h);
            joins.push(std::thread::spawn(move || {
                let mut rng = Pcg32::seed(0x4a44 + t as u64);
                for _ in 0..per {
                    h.observe(1e-5 * (1.0 + rng.next_f64() * 1e4));
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, threads as u64 * per);
        assert_eq!(snap.buckets.iter().sum::<u64>(), threads as u64 * per);
    }

    #[test]
    fn take_drains_and_resets() {
        let h = LogHistogram::latency();
        for i in 1..=100 {
            h.observe(i as f64 * 1e-4);
        }
        let first = h.take();
        assert_eq!(first.count, 100);
        assert!(first.sum > 0.0);
        let second = h.take();
        assert_eq!(second.count, 0);
        assert_eq!(second.sum, 0.0);
        assert_eq!(h.count(), 0);
        assert_eq!(second.percentile(0.5), None);
    }

    #[test]
    fn empty_percentiles_are_none() {
        let snap = LogHistogram::latency().snapshot();
        assert_eq!(snap.p50(), None);
        assert_eq!(snap.p99(), None);
        assert_eq!(snap.mean(), None);
    }
}
