//! Scalar-quantization baselines (paper §3.2, §4.1): symmetric per-tensor
//! INT4 / INT8. These exist to reproduce the INT4/INT8 rows of Tables 1
//! and 4 — including the round-trip dequantization that LOOKAT avoids.

/// A scalar-quantized tensor: packed signed codes + one per-tensor scale.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    /// signed codes, one i8 per element (INT4 uses the low nibble range)
    pub codes: Vec<i8>,
    pub scale: f32,
    pub bits: u8,
}

impl QuantizedTensor {
    /// Storage bytes under ideal packing (INT4 packs two codes per byte).
    pub fn storage_bytes(&self) -> usize {
        match self.bits {
            4 => self.codes.len().div_ceil(2),
            8 => self.codes.len(),
            b => self.codes.len() * b as usize / 8,
        }
    }
}

/// Symmetric per-tensor quantization: scale maps max|x| to the top of the
/// signed range. Mirrors python/compile/kernels/quant.py.
pub fn quantize_symmetric(x: &[f32], bits: u8) -> QuantizedTensor {
    assert!(bits == 4 || bits == 8, "only INT4/INT8 baselines supported");
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let qmin = -qmax - 1.0;
    let amax = x.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    let scale = if amax > 0.0 { amax / qmax } else { 1.0 };
    let codes = x
        .iter()
        .map(|&v| (v / scale).round().clamp(qmin, qmax) as i8)
        .collect();
    QuantizedTensor { codes, scale, bits }
}

/// Dequantize back to f32: x ≈ code · scale. This round trip is the
/// bandwidth cost scalar quantization cannot avoid (paper §3.2).
pub fn dequantize(q: &QuantizedTensor) -> Vec<f32> {
    q.codes.iter().map(|&c| c as f32 * q.scale).collect()
}

/// quantize→dequantize in one call (what the INT4/INT8 rows do to keys
/// before exact attention).
pub fn quant_roundtrip(x: &[f32], bits: u8) -> Vec<f32> {
    dequantize(&quantize_symmetric(x, bits))
}

/// Bytes/token for a scalar-quantized key of dimension `d_k`.
pub fn bytes_per_token(d_k: usize, bits: u8) -> usize {
    (d_k * bits as usize).div_ceil(8)
}

/// Compression ratio vs FP16 keys.
pub fn compression_ratio(bits: u8) -> f64 {
    16.0 / bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn sample(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seed(seed);
        (0..n).map(|_| rng.next_f32_std() * 3.0).collect()
    }

    #[test]
    fn int8_roundtrip_error_bounded_by_half_scale() {
        let x = sample(4096, 1);
        let q = quantize_symmetric(&x, 8);
        let y = dequantize(&q);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() <= q.scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn int4_coarser_than_int8() {
        let x = sample(4096, 2);
        let mse = |y: &[f32]| -> f64 {
            x.iter()
                .zip(y)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / x.len() as f64
        };
        let e4 = mse(&quant_roundtrip(&x, 4));
        let e8 = mse(&quant_roundtrip(&x, 8));
        assert!(e4 > e8 * 10.0, "e4={e4} e8={e8}");
    }

    #[test]
    fn codes_respect_bit_range() {
        let x = sample(1000, 3);
        let q4 = quantize_symmetric(&x, 4);
        assert!(q4.codes.iter().all(|&c| (-8..=7).contains(&c)));
        let q8 = quantize_symmetric(&x, 8);
        assert!(q8.codes.iter().all(|&c| (-128..=127).contains(&c)));
    }

    #[test]
    fn zero_tensor_is_stable() {
        let q = quantize_symmetric(&[0.0; 64], 4);
        assert_eq!(q.scale, 1.0);
        assert!(dequantize(&q).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn max_element_is_exactly_representable() {
        let x = [1.0f32, -0.5, 0.25, 127.0];
        let y = quant_roundtrip(&x, 8);
        assert!((y[3] - 127.0).abs() < 1e-4);
    }

    #[test]
    fn storage_and_compression_accounting() {
        // Exact accounting: FP16 key (d_k=64) = 128 B; INT8 = 64 B (2x),
        // INT4 = 32 B (4x). NOTE: the paper's Table 1 lists INT8 = 8x/16 B
        // and INT4 = 16x/8 B, which is arithmetically inconsistent with
        // d_k=64 scalar quantization; we report exact bytes and flag the
        // discrepancy in EXPERIMENTS.md (the qualitative shape — scalar
        // methods cannot reach the >=32x regime — is unchanged, indeed
        // strengthened).
        assert_eq!(bytes_per_token(64, 8), 64);
        assert_eq!(bytes_per_token(64, 4), 32);
        assert_eq!(compression_ratio(8), 2.0);
        assert_eq!(compression_ratio(4), 4.0);
        let q = quantize_symmetric(&vec![1.0; 64], 8);
        assert_eq!(q.storage_bytes(), 64);
        let q4 = quantize_symmetric(&vec![1.0; 64], 4);
        assert_eq!(q4.storage_bytes(), 32);
    }

    #[test]
    #[should_panic(expected = "only INT4/INT8")]
    fn rejects_unsupported_bits() {
        quantize_symmetric(&[1.0], 2);
    }
}
