//! Asymmetric distance computation: per-query lookup tables and the
//! batched code-scan that replaces the Q·Kᵀ matmul (paper §3.5, Alg. 1).
//!
//! This is the L3 hot path. Two scan layouts exist:
//!
//! * **token-major** ([`LookupTable::scores_into`]): codes are (n × m)
//!   row-major, one token's m codes contiguous. The reference layout —
//!   gathers, PJRT packing and the attention primitives use it.
//! * **subspace-major fast-scan** ([`LookupTable::scores_lanes`]): codes
//!   arrive as (m × G) lanes (vector-database "fast scan" layout, the
//!   paged cache's block-resident form). The inner loop walks one LUT
//!   row over G tokens, so a single (K,) row stays register/L1-resident
//!   while the uint8 codes stream — the bandwidth story the paper
//!   claims (m bytes/key instead of 2·d_k), now with the LUT access
//!   pattern to match.
//!
//! Every kernel accumulates each token's subspaces **in order 0..m
//! (strict left-to-right)**, so all paths — [`LookupTable::score`],
//! [`LookupTable::scores_into`] (all unrolled `m` specializations),
//! [`LookupTable::scores_lanes`] and the nibble-packed
//! [`LookupTable::scores_lanes_packed`] — produce bit-identical f32
//! scores, on the SIMD and the scalar dispatch alike (the lane scans
//! vectorize *across tokens* via [`super::simd`], never across a
//! token's subspaces).

use super::Codebook;

/// Per-query ADC lookup tables: `table[i*k + c] = q^(i) · C_i[c]`.
#[derive(Clone, Debug)]
pub struct LookupTable {
    pub m: usize,
    pub k: usize,
    table: Vec<f32>,
}

impl LookupTable {
    /// Precompute the tables for one query (paper Alg. 1 lines 1–4).
    /// Cost: m · K · d_sub MACs, once per query.
    ///
    /// Uses the codebook's transposed layout: each table row accumulates
    /// `d_sub` K-wide axpy passes (`LUT_i += q[d] · Cᵢᵀ[d, :]`), which
    /// LLVM vectorizes, instead of K short d_sub-element dot products
    /// whose call overhead dominated the original profile (§Perf: 17 µs
    /// → ~2 µs for m=4, K=256).
    pub fn build(query: &[f32], cb: &Codebook) -> LookupTable {
        Self::build_into(query, cb, Vec::new())
    }

    /// [`LookupTable::build`] reusing a scratch buffer for the table
    /// storage (the decode kernels recycle tables through the
    /// thread pool's [`crate::util::threadpool::ScratchPool`], so the
    /// steady-state tick allocates no LUT memory). The buffer is
    /// cleared and resized; its prior contents are irrelevant.
    pub fn build_into(
        query: &[f32],
        cb: &Codebook,
        mut table: Vec<f32>,
    ) -> LookupTable {
        assert_eq!(query.len(), cb.d_k(), "query/codebook dim mismatch");
        let (m, k, d_sub) = (cb.m, cb.k, cb.d_sub);
        table.clear();
        table.resize(m * k, 0.0);
        for i in 0..m {
            let q_sub = &query[i * d_sub..(i + 1) * d_sub];
            let ct = cb.subspace_t(i); // (d_sub × K)
            let row = &mut table[i * k..(i + 1) * k];
            for (d, &qv) in q_sub.iter().enumerate() {
                if qv != 0.0 {
                    crate::tensor::axpy(row, qv, &ct[d * k..(d + 1) * k]);
                }
            }
        }
        LookupTable { m, k, table }
    }

    /// Recover the table storage for recycling (see
    /// [`LookupTable::build_into`]).
    pub fn into_table(self) -> Vec<f32> {
        self.table
    }

    /// Raw table access (PJRT boundary, tests).
    pub fn as_slice(&self) -> &[f32] {
        &self.table
    }

    /// Score one key: `Σ_i LUT_i[codes[i]]` (Alg. 1 line 7),
    /// accumulated in subspace order 0..m.
    #[inline]
    pub fn score(&self, codes: &[u8]) -> f32 {
        debug_assert_eq!(codes.len(), self.m);
        let mut s = 0.0f32;
        for (i, &c) in codes.iter().enumerate() {
            s += self.table[i * self.k + c as usize];
        }
        s
    }

    /// Batched token-major scan: scores for `n` keys with row-major
    /// codes (n × m).
    ///
    /// Specialized kernels for the paper's subspace counts keep the
    /// loop free of generic inner-loop bounds checks; the generic-`m`
    /// path is the same inlined loop without the compile-time unroll
    /// (no per-token function call). All paths accumulate subspaces
    /// strictly left-to-right, bit-identical to [`LookupTable::score`]
    /// and to the subspace-major [`LookupTable::scores_lanes`].
    pub fn scores_into(&self, codes: &[u8], n: usize, out: &mut [f32]) {
        assert_eq!(codes.len(), n * self.m);
        assert!(out.len() >= n);
        match self.m {
            2 => self.scores_fixed::<2>(codes, n, out),
            4 => self.scores_fixed::<4>(codes, n, out),
            8 => self.scores_fixed::<8>(codes, n, out),
            16 => self.scores_fixed::<16>(codes, n, out),
            _ => self.scores_generic(codes, n, out),
        }
    }

    /// Token-major kernel with a compile-time subspace count: the
    /// sequential accumulation unrolls fully and the per-token code
    /// slice becomes a fixed-size array (no bounds checks).
    fn scores_fixed<const M: usize>(
        &self,
        codes: &[u8],
        n: usize,
        out: &mut [f32],
    ) {
        let k = self.k;
        let t = &self.table[..];
        for (l, o) in out.iter_mut().enumerate().take(n) {
            let c: &[u8; M] =
                codes[l * M..l * M + M].try_into().unwrap();
            let mut s = t[c[0] as usize];
            for i in 1..M {
                s += t[i * k + c[i] as usize];
            }
            *o = s;
        }
    }

    /// Token-major kernel for arbitrary `m` — the same loop as
    /// [`LookupTable::scores_fixed`] without the unroll (and without
    /// the retired per-token `score()` call of earlier revisions).
    fn scores_generic(&self, codes: &[u8], n: usize, out: &mut [f32]) {
        let (m, k) = (self.m, self.k);
        let t = &self.table[..];
        for (l, o) in out.iter_mut().enumerate().take(n) {
            let c = &codes[l * m..(l + 1) * m];
            let mut s = t[c[0] as usize];
            for (i, &ci) in c.iter().enumerate().skip(1) {
                s += t[i * k + ci as usize];
            }
            *o = s;
        }
    }

    /// Convenience allocating wrapper around [`scores_into`].
    ///
    /// [`scores_into`]: LookupTable::scores_into
    pub fn scores(&self, codes: &[u8], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        self.scores_into(codes, n, &mut out);
        out
    }

    /// Subspace-major fast scan: append scores for a stream of code
    /// *lanes*.
    ///
    /// Each lane is the `(m × stride)` row-major code matrix of one
    /// group of tokens (the paged cache's per-block layout,
    /// `BlockView::codes`): row `i` holds subspace `i`'s codes for the
    /// group, and only the first `len` entries of each row are valid
    /// (`stride` is inferred as `lane.len() / m`). *Any* lane may
    /// claim `len < stride` — a sequence's partial last block, but
    /// also an interior block cut short by a span row's causal-prefix
    /// truncation (the kernels shorten `len` mid-stream rather than
    /// scoring tokens a prefill row must not attend). The outer loop walks
    /// subspaces, so one (K,) LUT row stays hot while `len` codes
    /// stream through a branch-free inner loop — and because token `t`
    /// still receives its subspace terms in order 0..m, the result is
    /// bit-identical to the token-major [`LookupTable::scores_into`]
    /// over the gathered equivalent.
    ///
    /// Lane geometry is checked with *release-mode* asserts: a corrupt
    /// block lane aborts instead of silently misscoring (this replaced
    /// a `debug_assert!` that vanished in release builds).
    pub fn scores_lanes<'a, I>(&self, lanes: I, out: &mut Vec<f32>)
    where
        I: IntoIterator<Item = (&'a [u8], usize)>,
    {
        self.scores_lanes_impl(lanes, out, false)
    }

    /// [`LookupTable::scores_lanes`] pinned to the scalar kernels — the
    /// reference the SIMD dispatch is property-tested against, and the
    /// baseline series in `benches/adc_scan.rs`.
    pub fn scores_lanes_scalar<'a, I>(&self, lanes: I, out: &mut Vec<f32>)
    where
        I: IntoIterator<Item = (&'a [u8], usize)>,
    {
        self.scores_lanes_impl(lanes, out, true)
    }

    fn scores_lanes_impl<'a, I>(
        &self,
        lanes: I,
        out: &mut Vec<f32>,
        force_scalar: bool,
    ) where
        I: IntoIterator<Item = (&'a [u8], usize)>,
    {
        let (m, k) = (self.m, self.k);
        for (lane, len) in lanes {
            assert_eq!(
                lane.len() % m,
                0,
                "code lane misaligned: {} bytes for m={m}",
                lane.len()
            );
            let stride = lane.len() / m;
            assert!(
                len <= stride,
                "lane claims {len} tokens but has stride {stride}"
            );
            let start = out.len();
            out.resize(start + len, 0.0);
            let dst = &mut out[start..];
            for i in 0..m {
                let row = &self.table[i * k..(i + 1) * k];
                let codes_i = &lane[i * stride..i * stride + len];
                gather_accumulate(row, codes_i, dst, i == 0, force_scalar);
            }
        }
    }

    /// Nibble-packed subspace-major fast scan for K ≤ 16 codecs: the
    /// register-resident shuffle path.
    ///
    /// Same contract as [`LookupTable::scores_lanes`], but each lane is
    /// the `(m × stride_bytes)` row-major *packed* code matrix of one
    /// token group: row `i` holds subspace `i`'s 4-bit codes two per
    /// byte (low nibble = even token, high nibble = odd token), so a
    /// lane addresses up to `2 · stride_bytes` tokens and only the
    /// first `len` are valid. Odd `len` — a partial tail or a
    /// causal-prefix truncation landing mid-byte — leaves the final
    /// byte's high nibble ignored. On AVX2 the entire quantized LUT row
    /// (16 f32) lives in registers and each lookup is a shuffle
    /// ([`super::simd::nibble_accumulate`]); the scalar path is
    /// bit-identical and stays the source of truth.
    pub fn scores_lanes_packed<'a, I>(&self, lanes: I, out: &mut Vec<f32>)
    where
        I: IntoIterator<Item = (&'a [u8], usize)>,
    {
        self.scores_lanes_packed_impl(lanes, out, false)
    }

    /// [`LookupTable::scores_lanes_packed`] pinned to the scalar
    /// nibble kernel (reference + bench baseline).
    pub fn scores_lanes_packed_scalar<'a, I>(
        &self,
        lanes: I,
        out: &mut Vec<f32>,
    ) where
        I: IntoIterator<Item = (&'a [u8], usize)>,
    {
        self.scores_lanes_packed_impl(lanes, out, true)
    }

    fn scores_lanes_packed_impl<'a, I>(
        &self,
        lanes: I,
        out: &mut Vec<f32>,
        force_scalar: bool,
    ) where
        I: IntoIterator<Item = (&'a [u8], usize)>,
    {
        let (m, k) = (self.m, self.k);
        assert!(
            super::packs_nibbles(k),
            "packed scan needs K <= 16 (4-bit codes); this LUT has K={k}"
        );
        for (lane, len) in lanes {
            assert_eq!(
                lane.len() % m,
                0,
                "packed code lane misaligned: {} bytes for m={m}",
                lane.len()
            );
            let stride = lane.len() / m;
            assert!(
                len <= 2 * stride,
                "packed lane claims {len} tokens but holds at most {}",
                2 * stride
            );
            let start = out.len();
            out.resize(start + len, 0.0);
            let dst = &mut out[start..];
            for i in 0..m {
                // the (≤16,) LUT row, zero-padded to the register shape
                let mut row16 = [0.0f32; 16];
                row16[..k].copy_from_slice(&self.table[i * k..(i + 1) * k]);
                let packed_i = &lane[i * stride..(i + 1) * stride];
                if force_scalar {
                    super::simd::nibble_accumulate_scalar(
                        &row16, packed_i, len, dst, i == 0,
                    );
                } else {
                    super::simd::nibble_accumulate(
                        &row16, packed_i, len, dst, i == 0,
                    );
                }
            }
        }
    }
}

/// One fast-scan pass: `dst[t] (=|+=) row[codes[t]]`. The K = 256 case
/// goes through [`super::simd::gather_accumulate`] — an 8-wide
/// `vgatherdps` on AVX2, the bounds-check-free scalar loop otherwise
/// (every u8 index is valid against a 256-row). Smaller K keeps the
/// bounds-checked scalar loop: a corrupt over-K code must abort, and
/// the packed shuffle path covers K ≤ 16 anyway.
#[inline]
fn gather_accumulate(
    row: &[f32],
    codes: &[u8],
    dst: &mut [f32],
    first: bool,
    force_scalar: bool,
) {
    if let Ok(row) = <&[f32; 256]>::try_from(row) {
        if force_scalar {
            super::simd::gather_accumulate_scalar(row, codes, dst, first);
        } else {
            super::simd::gather_accumulate(row, codes, dst, first);
        }
    } else if first {
        for (o, &c) in dst.iter_mut().zip(codes) {
            *o = row[c as usize];
        }
    } else {
        for (o, &c) in dst.iter_mut().zip(codes) {
            *o += row[c as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::{PqCodec, TrainOpts};
    use crate::util::rng::Pcg32;

    fn setup(m: usize) -> (Vec<f32>, PqCodec, Vec<f32>, Vec<u8>, usize) {
        let d_k = 64;
        let n = 200;
        let mut rng = Pcg32::seed(99);
        let keys: Vec<f32> =
            (0..n * d_k).map(|_| rng.next_f32_std()).collect();
        let codec = PqCodec::train(&keys, d_k, m, 64, &TrainOpts::default());
        let codes = codec.encode_batch(&keys, n);
        let query: Vec<f32> = (0..d_k).map(|_| rng.next_f32_std()).collect();
        (query, codec, keys, codes, n)
    }

    use crate::testkit::fixtures::interleave_lanes as to_lanes;

    #[test]
    fn lut_entries_are_subspace_dots() {
        let (query, codec, _, _, _) = setup(4);
        let lut = LookupTable::build(&query, &codec.codebook);
        let cb = &codec.codebook;
        for i in 0..4 {
            for c in [0usize, 7, 63] {
                let want = crate::tensor::dot(
                    &query[i * cb.d_sub..(i + 1) * cb.d_sub],
                    cb.centroid(i, c),
                );
                let got = lut.as_slice()[i * cb.k + c];
                assert!((got - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn build_into_reuses_storage_and_matches_build() {
        let (query, codec, _, _, _) = setup(4);
        let fresh = LookupTable::build(&query, &codec.codebook);
        // dirty, differently-sized scratch must not leak into the table
        let scratch = vec![7.5f32; 13];
        let reused =
            LookupTable::build_into(&query, &codec.codebook, scratch);
        assert_eq!(fresh.as_slice(), reused.as_slice());
        let recovered = reused.into_table();
        assert_eq!(recovered.len(), 4 * 64);
    }

    #[test]
    fn adc_score_equals_dot_with_reconstruction() {
        // s_l = q · decode(codes_l) exactly (ADC is exact on reconstructions)
        for m in [2usize, 4, 8, 16] {
            let (query, codec, _, codes, n) = setup(m);
            let lut = LookupTable::build(&query, &codec.codebook);
            for l in (0..n).step_by(17) {
                let code = &codes[l * m..(l + 1) * m];
                let recon = codec.decode(code);
                let want = crate::tensor::dot(&query, &recon);
                let got = lut.score(code);
                assert!(
                    (got - want).abs() < 1e-4,
                    "m={m} l={l}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn batched_scan_bit_identical_to_scalar_all_specializations() {
        // every unrolled kernel and the generic path accumulate in
        // subspace order 0..m, so the batch is *bit-identical* to the
        // scalar score() — not merely close (m = 32 exercises generic)
        for m in [2usize, 4, 8, 16, 32] {
            let (query, codec, _, codes, n) = setup(m);
            let m_eff = codec.codebook.m;
            assert_eq!(m_eff, m);
            let lut = LookupTable::build(&query, &codec.codebook);
            let batch = lut.scores(&codes, n);
            for l in 0..n {
                let s = lut.score(&codes[l * m_eff..(l + 1) * m_eff]);
                assert_eq!(
                    batch[l].to_bits(),
                    s.to_bits(),
                    "m={m} l={l}"
                );
            }
        }
    }

    #[test]
    fn lane_scan_bit_identical_to_flat_scan() {
        for m in [2usize, 4, 8, 16, 32] {
            let (query, codec, _, codes, n) = setup(m);
            let lut = LookupTable::build(&query, &codec.codebook);
            let flat = lut.scores(&codes, n);
            // uneven group sizes, last lane partial — the paged shape
            for gt in [32usize, 48, 200, 7] {
                let lanes = to_lanes(&codes, m, gt);
                let mut out = Vec::new();
                lut.scores_lanes(
                    lanes.iter().map(|(l, n)| (&l[..], *n)),
                    &mut out,
                );
                assert_eq!(flat.len(), out.len());
                for (a, b) in flat.iter().zip(&out) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "m={m} group_tokens={gt}"
                    );
                }
            }
        }
    }

    fn setup_k16(m: usize) -> (LookupTable, Vec<u8>, usize) {
        let d_k = 64;
        let n = 200;
        let mut rng = Pcg32::seed(0x416 + m as u64);
        let keys: Vec<f32> =
            (0..n * d_k).map(|_| rng.next_f32_std()).collect();
        let codec =
            PqCodec::train(&keys, d_k, m, 16, &TrainOpts::default());
        let codes = codec.encode_batch(&keys, n);
        let query: Vec<f32> =
            (0..d_k).map(|_| rng.next_f32_std()).collect();
        let lut = LookupTable::build(&query, &codec.codebook);
        (lut, codes, n)
    }

    #[test]
    fn packed_scan_bit_identical_to_flat_for_every_m() {
        use crate::testkit::fixtures::interleave_lanes_packed;
        for m in [2usize, 4, 8, 16, 32] {
            let (lut, codes, n) = setup_k16(m);
            let flat = lut.scores(&codes, n);
            // even/odd tails, tiny groups, one giant group
            for gt in [32usize, 48, 200, 6] {
                let lanes = interleave_lanes_packed(&codes, m, gt);
                for scalar in [false, true] {
                    let mut out = Vec::new();
                    let it = lanes.iter().map(|(l, n)| (&l[..], *n));
                    if scalar {
                        lut.scores_lanes_packed_scalar(it, &mut out);
                    } else {
                        lut.scores_lanes_packed(it, &mut out);
                    }
                    assert_eq!(flat.len(), out.len());
                    for (t, (a, b)) in flat.iter().zip(&out).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "m={m} group={gt} scalar={scalar} t={t}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn packed_scan_honors_mid_stream_truncation() {
        // a causal-prefix cut can shorten ANY lane, including to an odd
        // length whose final byte has a live low nibble and a dead high
        // nibble — scores must match the flat scan over the same prefix
        use crate::testkit::fixtures::interleave_lanes_packed;
        let m = 4;
        let (lut, codes, _) = setup_k16(m);
        let lanes = interleave_lanes_packed(&codes, m, 32);
        for cut in [31usize, 32, 33, 40, 45, 64, 65] {
            let mut out = Vec::new();
            let mut left = cut;
            lut.scores_lanes_packed(
                lanes.iter().filter_map(|(l, n)| {
                    if left == 0 {
                        return None;
                    }
                    let take = (*n).min(left);
                    left -= take;
                    Some((&l[..], take))
                }),
                &mut out,
            );
            let flat = lut.scores(&codes[..cut * m], cut);
            assert_eq!(out.len(), cut);
            for (a, b) in flat.iter().zip(&out) {
                assert_eq!(a.to_bits(), b.to_bits(), "cut={cut}");
            }
        }
    }

    #[test]
    fn byte_lane_scan_simd_matches_scalar_k256() {
        // dispatched (possibly AVX2 gather) vs pinned-scalar on the
        // full-width K=256 path
        let d_k = 64;
        let n = 203; // not a multiple of 8: exercises the vector tail
        let mut rng = Pcg32::seed(0x256);
        let m = 8;
        let d_sub = d_k / m;
        let centroids: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                (0..256 * d_sub).map(|_| rng.next_f32_std()).collect()
            })
            .collect();
        let cb = Codebook::new(m, 256, d_sub, centroids);
        let query: Vec<f32> =
            (0..d_k).map(|_| rng.next_f32_std()).collect();
        let lut = LookupTable::build(&query, &cb);
        let codes: Vec<u8> =
            (0..n * m).map(|_| rng.next_bounded(256) as u8).collect();
        let lanes = to_lanes(&codes, m, 32);
        let (mut fast, mut slow) = (Vec::new(), Vec::new());
        lut.scores_lanes(
            lanes.iter().map(|(l, n)| (&l[..], *n)),
            &mut fast,
        );
        lut.scores_lanes_scalar(
            lanes.iter().map(|(l, n)| (&l[..], *n)),
            &mut slow,
        );
        assert_eq!(fast.len(), slow.len());
        for (a, b) in fast.iter().zip(&slow) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "needs K <= 16")]
    fn packed_scan_rejects_wide_codebooks() {
        let (query, codec, _, _, _) = setup(4); // K = 64
        let lut = LookupTable::build(&query, &codec.codebook);
        let mut out = Vec::new();
        lut.scores_lanes_packed([(&[0u8; 8][..], 2)], &mut out);
    }

    #[test]
    #[should_panic(expected = "misaligned")]
    fn packed_scan_rejects_misaligned_lane() {
        let (lut, _, _) = setup_k16(4);
        let mut out = Vec::new();
        lut.scores_lanes_packed([(&[0u8; 7][..], 1)], &mut out);
    }

    #[test]
    #[should_panic(expected = "holds at most")]
    fn packed_scan_rejects_overlong_len() {
        let (lut, _, _) = setup_k16(4);
        let mut out = Vec::new();
        // 8 bytes = 2 per subspace = 4 tokens max, but claims 5
        lut.scores_lanes_packed([(&[0u8; 8][..], 5)], &mut out);
    }

    #[test]
    #[should_panic(expected = "misaligned")]
    fn lane_scan_rejects_misaligned_lane_in_release_too() {
        let (query, codec, _, _, _) = setup(4);
        let lut = LookupTable::build(&query, &codec.codebook);
        let mut out = Vec::new();
        // 7 bytes is not a multiple of m=4: must abort, not misscore
        lut.scores_lanes([(&[0u8; 7][..], 1)], &mut out);
    }

    #[test]
    #[should_panic(expected = "stride")]
    fn lane_scan_rejects_overlong_len() {
        let (query, codec, _, _, _) = setup(4);
        let lut = LookupTable::build(&query, &codec.codebook);
        let mut out = Vec::new();
        // lane holds 2 tokens per subspace but claims 3
        lut.scores_lanes([(&[0u8; 8][..], 3)], &mut out);
    }

    #[test]
    fn adc_approximates_exact_scores_with_trained_codebook() {
        let (query, codec, keys, codes, n) = setup(8);
        let lut = LookupTable::build(&query, &codec.codebook);
        let approx = lut.scores(&codes, n);
        // rank correlation between exact and ADC scores should be high
        let exact: Vec<f32> = (0..n)
            .map(|l| crate::tensor::dot(&query, &keys[l * 64..(l + 1) * 64]))
            .collect();
        let rho = crate::metrics::spearman_rho(
            &exact.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &approx.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!(rho > 0.8, "spearman {rho} too low");
    }

    #[test]
    fn zero_query_gives_zero_scores() {
        let (_, codec, _, codes, n) = setup(4);
        let lut = LookupTable::build(&vec![0.0; 64], &codec.codebook);
        for s in lut.scores(&codes, n) {
            assert_eq!(s, 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn build_rejects_wrong_query_dim() {
        let (_, codec, _, _, _) = setup(4);
        LookupTable::build(&vec![0.0; 32], &codec.codebook);
    }
}
