//! Asymmetric distance computation: per-query lookup tables and the
//! batched code-scan that replaces the Q·Kᵀ matmul (paper §3.5, Alg. 1).
//!
//! This is the L3 hot path. The scan is specialized for the paper's
//! m ∈ {2,4,8,16} with unrolled inner loops; the LUT (m × K f32 ≤ 16 KB)
//! stays resident in L1/L2 while the uint8 codes stream through — the
//! bandwidth story the paper claims (m bytes/key instead of 2·d_k).

use super::Codebook;

/// Per-query ADC lookup tables: `table[i*k + c] = q^(i) · C_i[c]`.
#[derive(Clone, Debug)]
pub struct LookupTable {
    pub m: usize,
    pub k: usize,
    table: Vec<f32>,
}

impl LookupTable {
    /// Precompute the tables for one query (paper Alg. 1 lines 1–4).
    /// Cost: m · K · d_sub MACs, once per query.
    ///
    /// Uses the codebook's transposed layout: each table row accumulates
    /// `d_sub` K-wide axpy passes (`LUT_i += q[d] · Cᵢᵀ[d, :]`), which
    /// LLVM vectorizes, instead of K short d_sub-element dot products
    /// whose call overhead dominated the original profile (§Perf: 17 µs
    /// → ~2 µs for m=4, K=256).
    pub fn build(query: &[f32], cb: &Codebook) -> LookupTable {
        assert_eq!(query.len(), cb.d_k(), "query/codebook dim mismatch");
        let (m, k, d_sub) = (cb.m, cb.k, cb.d_sub);
        let mut table = vec![0.0f32; m * k];
        for i in 0..m {
            let q_sub = &query[i * d_sub..(i + 1) * d_sub];
            let ct = cb.subspace_t(i); // (d_sub × K)
            let row = &mut table[i * k..(i + 1) * k];
            for (d, &qv) in q_sub.iter().enumerate() {
                if qv != 0.0 {
                    crate::tensor::axpy(row, qv, &ct[d * k..(d + 1) * k]);
                }
            }
        }
        LookupTable { m, k, table }
    }

    /// Raw table access (PJRT boundary, tests).
    pub fn as_slice(&self) -> &[f32] {
        &self.table
    }

    /// Score one key: `Σ_i LUT_i[codes[i]]` (Alg. 1 line 7).
    #[inline]
    pub fn score(&self, codes: &[u8]) -> f32 {
        debug_assert_eq!(codes.len(), self.m);
        let mut s = 0.0f32;
        for (i, &c) in codes.iter().enumerate() {
            s += self.table[i * self.k + c as usize];
        }
        s
    }

    /// Batched scan: scores for `n` keys with row-major codes (n × m).
    ///
    /// Specialized unrolled kernels for the paper's subspace counts keep
    /// the loop free of the generic inner-loop bounds checks.
    pub fn scores_into(&self, codes: &[u8], n: usize, out: &mut [f32]) {
        assert_eq!(codes.len(), n * self.m);
        assert!(out.len() >= n);
        let k = self.k;
        let t = &self.table[..];
        match self.m {
            2 => {
                let (t0, t1) = (&t[0..k], &t[k..2 * k]);
                for l in 0..n {
                    let c = &codes[l * 2..l * 2 + 2];
                    out[l] = t0[c[0] as usize] + t1[c[1] as usize];
                }
            }
            4 => {
                for l in 0..n {
                    let c = &codes[l * 4..l * 4 + 4];
                    out[l] = t[c[0] as usize]
                        + t[k + c[1] as usize]
                        + t[2 * k + c[2] as usize]
                        + t[3 * k + c[3] as usize];
                }
            }
            8 => {
                for l in 0..n {
                    let c = &codes[l * 8..l * 8 + 8];
                    let a = t[c[0] as usize] + t[k + c[1] as usize];
                    let b = t[2 * k + c[2] as usize]
                        + t[3 * k + c[3] as usize];
                    let d = t[4 * k + c[4] as usize]
                        + t[5 * k + c[5] as usize];
                    let e = t[6 * k + c[6] as usize]
                        + t[7 * k + c[7] as usize];
                    out[l] = (a + b) + (d + e);
                }
            }
            16 => {
                for l in 0..n {
                    let c = &codes[l * 16..l * 16 + 16];
                    let mut acc = 0.0f32;
                    let mut acc2 = 0.0f32;
                    for i in (0..16).step_by(2) {
                        acc += t[i * k + c[i] as usize];
                        acc2 += t[(i + 1) * k + c[i + 1] as usize];
                    }
                    out[l] = acc + acc2;
                }
            }
            m => {
                for l in 0..n {
                    out[l] = self.score(&codes[l * m..(l + 1) * m]);
                }
            }
        }
    }

    /// Convenience allocating wrapper around [`scores_into`].
    pub fn scores(&self, codes: &[u8], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n];
        self.scores_into(codes, n, &mut out);
        out
    }

    /// Block-resident scan: append scores for each code block in turn.
    ///
    /// The slices come straight from the paged cache
    /// (`KvCache::blocks`), so the serving hot path scans the codes
    /// where they live — no gather into contiguous scratch. Each block
    /// is a (len × m) row-major code slice; per-token results are
    /// bit-identical to one contiguous [`LookupTable::scores_into`]
    /// pass over the gathered equivalent, because every token's score
    /// is computed independently by the same unrolled kernels.
    pub fn scores_blocks<'a, I>(&self, blocks: I, out: &mut Vec<f32>)
    where
        I: IntoIterator<Item = &'a [u8]>,
    {
        for codes in blocks {
            debug_assert_eq!(codes.len() % self.m, 0);
            let n = codes.len() / self.m;
            let start = out.len();
            out.resize(start + n, 0.0);
            self.scores_into(codes, n, &mut out[start..]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::{PqCodec, TrainOpts};
    use crate::util::rng::Pcg32;

    fn setup(m: usize) -> (Vec<f32>, PqCodec, Vec<f32>, Vec<u8>, usize) {
        let d_k = 64;
        let n = 200;
        let mut rng = Pcg32::seed(99);
        let keys: Vec<f32> =
            (0..n * d_k).map(|_| rng.next_f32_std()).collect();
        let codec = PqCodec::train(&keys, d_k, m, 64, &TrainOpts::default());
        let codes = codec.encode_batch(&keys, n);
        let query: Vec<f32> = (0..d_k).map(|_| rng.next_f32_std()).collect();
        (query, codec, keys, codes, n)
    }

    #[test]
    fn lut_entries_are_subspace_dots() {
        let (query, codec, _, _, _) = setup(4);
        let lut = LookupTable::build(&query, &codec.codebook);
        let cb = &codec.codebook;
        for i in 0..4 {
            for c in [0usize, 7, 63] {
                let want = crate::tensor::dot(
                    &query[i * cb.d_sub..(i + 1) * cb.d_sub],
                    cb.centroid(i, c),
                );
                let got = lut.as_slice()[i * cb.k + c];
                assert!((got - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn adc_score_equals_dot_with_reconstruction() {
        // s_l = q · decode(codes_l) exactly (ADC is exact on reconstructions)
        for m in [2usize, 4, 8, 16] {
            let (query, codec, _, codes, n) = setup(m);
            let lut = LookupTable::build(&query, &codec.codebook);
            for l in (0..n).step_by(17) {
                let code = &codes[l * m..(l + 1) * m];
                let recon = codec.decode(code);
                let want = crate::tensor::dot(&query, &recon);
                let got = lut.score(code);
                assert!(
                    (got - want).abs() < 1e-4,
                    "m={m} l={l}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn batched_scan_matches_scalar_all_specializations() {
        for m in [2usize, 4, 8, 16, 32] {
            let d_k = 64;
            if d_k % m != 0 {
                continue;
            }
            let (query, codec, _, codes, n) = setup(m.min(16));
            let m_eff = codec.codebook.m;
            let lut = LookupTable::build(&query, &codec.codebook);
            let batch = lut.scores(&codes, n);
            for l in 0..n {
                let s = lut.score(&codes[l * m_eff..(l + 1) * m_eff]);
                // unrolled kernels use pairwise sums; f32 reassociation
                // gives tiny differences vs the sequential scalar path
                assert!((batch[l] - s).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn blocked_scan_bit_identical_to_flat_scan() {
        for m in [2usize, 4, 8, 16] {
            let (query, codec, _, codes, n) = setup(m);
            let lut = LookupTable::build(&query, &codec.codebook);
            let flat = lut.scores(&codes, n);
            // uneven block sizes, last block partial — the paged shape
            for bt in [32usize, 48, 200, 7] {
                let mut blocked = Vec::new();
                lut.scores_blocks(codes.chunks(bt * m), &mut blocked);
                assert_eq!(flat, blocked, "m={m} block_tokens={bt}");
            }
        }
    }

    #[test]
    fn adc_approximates_exact_scores_with_trained_codebook() {
        let (query, codec, keys, codes, n) = setup(8);
        let lut = LookupTable::build(&query, &codec.codebook);
        let approx = lut.scores(&codes, n);
        // rank correlation between exact and ADC scores should be high
        let exact: Vec<f32> = (0..n)
            .map(|l| crate::tensor::dot(&query, &keys[l * 64..(l + 1) * 64]))
            .collect();
        let rho = crate::metrics::spearman_rho(
            &exact.iter().map(|&x| x as f64).collect::<Vec<_>>(),
            &approx.iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        assert!(rho > 0.8, "spearman {rho} too low");
    }

    #[test]
    fn zero_query_gives_zero_scores() {
        let (_, codec, _, codes, n) = setup(4);
        let lut = LookupTable::build(&vec![0.0; 64], &codec.codebook);
        for s in lut.scores(&codes, n) {
            assert_eq!(s, 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn build_rejects_wrong_query_dim() {
        let (_, codec, _, _, _) = setup(4);
        LookupTable::build(&vec![0.0; 32], &codec.codebook);
    }
}
