//! PQ training + encoding: keys -> m uint8 codes per key (paper §3.4).

use super::kmeans::kmeans;
use super::{Codebook, TrainOpts};
use crate::util::rng::Pcg32;

/// A trained product quantizer for one attention head.
#[derive(Clone, Debug)]
pub struct PqCodec {
    pub codebook: Codebook,
    /// mean squared reconstruction error on the calibration set, per
    /// subspace (diagnostics; drives the paper's O(d_k/mK) analysis).
    pub train_mse: Vec<f64>,
}

impl PqCodec {
    /// Train codebooks on calibration keys (`calib` is L × d_k row-major).
    ///
    /// Each subspace i gets its own K-Means over the L subvectors
    /// `k_l^(i)`, exactly the paper's prototype-learning step.
    pub fn train(
        calib: &[f32],
        d_k: usize,
        m: usize,
        k: usize,
        opts: &TrainOpts,
    ) -> PqCodec {
        assert!(d_k % m == 0, "d_k={d_k} not divisible by m={m}");
        if let Err(e) = super::codebook::validate_k(k) {
            panic!("{e}");
        }
        let d_sub = d_k / m;
        assert_eq!(calib.len() % d_k, 0);
        let n = calib.len() / d_k;
        assert!(n > 0, "empty calibration set");

        let mut centroids = Vec::with_capacity(m);
        let mut train_mse = Vec::with_capacity(m);
        for i in 0..m {
            // gather subspace i of every calibration key
            let mut sub = Vec::with_capacity(n * d_sub);
            for l in 0..n {
                let base = l * d_k + i * d_sub;
                sub.extend_from_slice(&calib[base..base + d_sub]);
            }
            let mut rng = Pcg32::seed(opts.seed ^ (i as u64) << 32);
            let res = kmeans(&sub, d_sub, k, opts.iters, opts.tol, &mut rng);
            train_mse.push(res.inertia / n as f64);
            centroids.push(res.centroids);
        }
        PqCodec {
            codebook: Codebook::new(m, k, d_sub, centroids),
            train_mse,
        }
    }

    /// Encode one key (d_k) to m codes.
    ///
    /// argmin‖x−c‖² = argmax(x·c − ‖c‖²/2): the dots against all K
    /// centroids come from d_sub K-wide axpy passes over the transposed
    /// codebook (§Perf: ~6.6 µs → ~1 µs per key at m=4, K=256), with
    /// ‖c‖² precomputed at codebook construction.
    pub fn encode(&self, key: &[f32]) -> Vec<u8> {
        let mut codes = vec![0u8; self.codebook.m];
        self.encode_into(key, &mut codes);
        codes
    }

    /// Allocation-free [`PqCodec::encode`] into a caller buffer of
    /// exactly `m` bytes (the per-subspace dot scratch comes from the
    /// shared thread-pool arena; callers on a serial hot path should
    /// prefer [`PqCodec::encode_into_with`] and own the scratch).
    pub fn encode_into(&self, key: &[f32], out: &mut [u8]) {
        let pool = crate::util::threadpool::scratch();
        let mut dots = pool.take_f32_any(self.codebook.k);
        self.encode_into_with(key, out, &mut dots);
        pool.put_f32(dots);
    }

    /// [`PqCodec::encode_into`] with caller-owned dot scratch —
    /// `dots` is resized to K and fully overwritten, so the cache
    /// append stage (serial, interleaved with the pipelined executor's
    /// worker fan-outs) encodes without touching the shared arena's
    /// mutex at all.
    pub fn encode_into_with(
        &self,
        key: &[f32],
        out: &mut [u8],
        dots: &mut Vec<f32>,
    ) {
        let cb = &self.codebook;
        assert_eq!(key.len(), cb.d_k());
        assert_eq!(out.len(), cb.m, "encode_into needs an m-byte buffer");
        let (k, d_sub) = (cb.k, cb.d_sub);
        dots.clear();
        dots.resize(k, 0.0);
        for (i, slot) in out.iter_mut().enumerate() {
            let sub = &key[i * d_sub..(i + 1) * d_sub];
            let ct = cb.subspace_t(i);
            dots.iter_mut().for_each(|v| *v = 0.0);
            for (d, &xv) in sub.iter().enumerate() {
                if xv != 0.0 {
                    crate::tensor::axpy(dots, xv, &ct[d * k..(d + 1) * k]);
                }
            }
            let norms = cb.norms2(i);
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for c in 0..k {
                let v = dots[c] - 0.5 * norms[c];
                if v > best_v {
                    best_v = v;
                    best = c;
                }
            }
            *slot = best as u8;
        }
    }

    /// Encode a batch of `n` keys (n × d_k row-major) -> (n × m) codes.
    pub fn encode_batch(&self, keys: &[f32], n: usize) -> Vec<u8> {
        let d_k = self.codebook.d_k();
        assert_eq!(keys.len(), n * d_k);
        let mut out = Vec::with_capacity(n * self.codebook.m);
        for l in 0..n {
            out.extend(self.encode(&keys[l * d_k..(l + 1) * d_k]));
        }
        out
    }

    /// Reconstruct an approximate key from its codes (for analysis only —
    /// the LOOKAT hot path never calls this; that's the whole point).
    pub fn decode(&self, codes: &[u8]) -> Vec<f32> {
        let cb = &self.codebook;
        assert_eq!(codes.len(), cb.m);
        let mut out = Vec::with_capacity(cb.d_k());
        for (i, &c) in codes.iter().enumerate() {
            out.extend_from_slice(cb.centroid(i, c as usize));
        }
        out
    }

    /// Mean squared reconstruction error over a key set.
    pub fn reconstruction_mse(&self, keys: &[f32], n: usize) -> f64 {
        let d_k = self.codebook.d_k();
        let mut total = 0.0f64;
        for l in 0..n {
            let key = &keys[l * d_k..(l + 1) * d_k];
            let recon = self.decode(&self.encode(key));
            total += crate::tensor::dist2(key, &recon) as f64;
        }
        total / n as f64
    }

    /// Whether this codec's codes are nibble-packed in the paged cache
    /// (K ≤ 16: two 4-bit codes per byte).
    pub fn packed(&self) -> bool {
        super::packs_nibbles(self.codebook.k)
    }

    /// Compressed bytes per token for this codec as stored: m codes at
    /// 1 B each for K > 16, or ⌈m/2⌉ B for nibble-packed K ≤ 16 codes.
    pub fn bytes_per_token(&self) -> usize {
        if self.packed() {
            self.codebook.m.div_ceil(2)
        } else {
            self.codebook.m
        }
    }

    /// Compression ratio vs FP16 keys (paper's headline metric):
    /// d_k · 2 bytes -> m bytes (K > 16) or m/2 bytes (4-bit codes).
    pub fn compression_ratio(&self) -> f64 {
        (self.codebook.d_k() * 2) as f64 / self.bytes_per_token() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_keys(n: usize, d_k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seed(seed);
        (0..n * d_k).map(|_| rng.next_f32_std()).collect()
    }

    #[test]
    fn codes_in_range_and_right_count() {
        let keys = gaussian_keys(300, 64, 1);
        let codec = PqCodec::train(&keys, 64, 4, 16, &TrainOpts::default());
        let codes = codec.encode_batch(&keys, 300);
        assert_eq!(codes.len(), 300 * 4);
        assert!(codes.iter().all(|&c| (c as usize) < 16));
    }

    #[test]
    fn compression_ratios_match_paper_table1() {
        let keys = gaussian_keys(64, 64, 2);
        // paper §4.1 at K > 16 (byte codes): LOOKAT-2 = 64x, -4 = 32x,
        // -8 = 16x, -16 = 8x
        for (m, want) in [(2usize, 64.0), (4, 32.0), (8, 16.0), (16, 8.0)] {
            let codec = PqCodec::train(
                &keys, 64, m, 32, &TrainOpts { iters: 3, ..Default::default() });
            assert!(!codec.packed());
            assert_eq!(codec.compression_ratio(), want);
            assert_eq!(codec.bytes_per_token(), m);
        }
    }

    #[test]
    fn packed_k16_halves_bytes_per_token() {
        // 4-bit codes: K=16 with doubled m matches K=256's bits per
        // token, so the stored bytes halve at equal m and the equal-bit
        // configurations line up (m, K=256) ↔ (2m, K=16)
        let keys = gaussian_keys(64, 64, 2);
        for (m, want_bytes, want_ratio) in
            [(2usize, 1usize, 128.0), (4, 2, 64.0), (8, 4, 32.0), (16, 8, 16.0)]
        {
            let codec = PqCodec::train(
                &keys, 64, m, 16, &TrainOpts { iters: 3, ..Default::default() });
            assert!(codec.packed());
            assert_eq!(codec.bytes_per_token(), want_bytes);
            assert_eq!(codec.compression_ratio(), want_ratio);
        }
    }

    #[test]
    fn roundtrip_exact_when_keys_are_centroids() {
        // train on a small set, then encode exactly those centroids
        let keys = gaussian_keys(32, 16, 3);
        let codec = PqCodec::train(&keys, 16, 4, 8, &TrainOpts::default());
        for c in 0..8 {
            let mut key = Vec::new();
            for i in 0..4 {
                key.extend_from_slice(codec.codebook.centroid(i, c));
            }
            let recon = codec.decode(&codec.encode(&key));
            for (a, b) in key.iter().zip(&recon) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn mse_decreases_with_k() {
        let keys = gaussian_keys(1000, 32, 4);
        let mut last = f64::INFINITY;
        for k in [4, 16, 64] {
            let codec = PqCodec::train(&keys, 32, 4, k,
                                       &TrainOpts::default());
            let mse = codec.reconstruction_mse(&keys, 1000);
            assert!(mse < last, "k={k}: {mse} !< {last}");
            last = mse;
        }
    }

    #[test]
    fn mse_decreases_with_m() {
        // more subspaces = finer quantization = lower reconstruction error
        let keys = gaussian_keys(1000, 32, 5);
        let mse_m2 = PqCodec::train(&keys, 32, 2, 32, &TrainOpts::default())
            .reconstruction_mse(&keys, 1000);
        let mse_m8 = PqCodec::train(&keys, 32, 8, 32, &TrainOpts::default())
            .reconstruction_mse(&keys, 1000);
        assert!(mse_m8 < mse_m2, "{mse_m8} !< {mse_m2}");
    }

    #[test]
    fn encode_picks_nearest_centroid() {
        let keys = gaussian_keys(100, 8, 6);
        let codec = PqCodec::train(&keys, 8, 2, 4, &TrainOpts::default());
        let key = &keys[0..8];
        let codes = codec.encode(key);
        for i in 0..2 {
            let sub = &key[i * 4..(i + 1) * 4];
            // brute force
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..4 {
                let d = crate::tensor::dist2(sub, codec.codebook.centroid(i, c));
                if d < best.0 {
                    best = (d, c);
                }
            }
            assert_eq!(codes[i] as usize, best.1);
        }
    }

    #[test]
    fn train_is_deterministic() {
        let keys = gaussian_keys(200, 16, 7);
        let a = PqCodec::train(&keys, 16, 4, 8, &TrainOpts::default());
        let b = PqCodec::train(&keys, 16, 4, 8, &TrainOpts::default());
        assert_eq!(a.codebook, b.codebook);
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn rejects_bad_m() {
        let keys = gaussian_keys(10, 10, 8);
        PqCodec::train(&keys, 10, 3, 4, &TrainOpts::default());
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn rejects_non_power_of_two_k() {
        let keys = gaussian_keys(10, 8, 9);
        PqCodec::train(&keys, 8, 2, 12, &TrainOpts::default());
    }
}
