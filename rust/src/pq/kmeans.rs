//! Lloyd's K-Means with k-means++ initialization, operating on flat
//! row-major point sets. This is the codebook learner of paper §3.4:
//!
//!   C_i = argmin_C  Σ_{k ∈ calib}  min_{c ∈ C} ||k^(i) − c||²

use crate::tensor::dist2;
use crate::util::rng::Pcg32;

/// K-Means result: centroids (k × dim, row-major) and final inertia.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub centroids: Vec<f32>,
    pub k: usize,
    pub dim: usize,
    pub inertia: f64,
    pub iters_run: usize,
}

/// Run k-means++ + Lloyd on `points` (n × dim row-major).
///
/// If n < k, surplus centroids are duplicated from sampled points — every
/// centroid is always a valid `dim`-vector, and encoding stays total.
pub fn kmeans(
    points: &[f32],
    dim: usize,
    k: usize,
    iters: usize,
    tol: f64,
    rng: &mut Pcg32,
) -> KMeansResult {
    assert!(dim > 0 && k > 0);
    assert_eq!(points.len() % dim, 0, "points not a multiple of dim");
    let n = points.len() / dim;
    assert!(n > 0, "kmeans needs at least one point");

    let mut centroids = init_pp(points, n, dim, k, rng);
    let mut assign = vec![0u32; n];
    let mut prev_inertia = f64::INFINITY;
    let mut inertia = f64::INFINITY;
    let mut iters_run = 0;

    for it in 0..iters {
        // assignment step
        inertia = 0.0;
        for p in 0..n {
            let pt = &points[p * dim..(p + 1) * dim];
            let (best, d) = nearest(pt, &centroids, k, dim);
            assign[p] = best as u32;
            inertia += d as f64;
        }
        iters_run = it + 1;

        // convergence check
        if prev_inertia.is_finite() {
            let rel = (prev_inertia - inertia) / prev_inertia.max(1e-30);
            if rel.abs() < tol {
                break;
            }
        }
        prev_inertia = inertia;

        // update step
        let mut counts = vec![0u32; k];
        let mut sums = vec![0.0f32; k * dim];
        for p in 0..n {
            let c = assign[p] as usize;
            counts[c] += 1;
            let pt = &points[p * dim..(p + 1) * dim];
            for (s, v) in sums[c * dim..(c + 1) * dim].iter_mut().zip(pt) {
                *s += *v;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f32;
                for d in 0..dim {
                    centroids[c * dim + d] = sums[c * dim + d] * inv;
                }
            } else {
                // dead centroid: respawn on a random point
                let p = rng.next_bounded(n as u32) as usize;
                centroids[c * dim..(c + 1) * dim]
                    .copy_from_slice(&points[p * dim..(p + 1) * dim]);
            }
        }
    }

    KMeansResult { centroids, k, dim, inertia, iters_run }
}

/// Index and squared distance of the nearest centroid.
#[inline]
pub fn nearest(pt: &[f32], centroids: &[f32], k: usize, dim: usize)
    -> (usize, f32)
{
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for c in 0..k {
        let d = dist2(pt, &centroids[c * dim..(c + 1) * dim]);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    (best, best_d)
}

/// k-means++ seeding (Arthur & Vassilvitskii 2007).
fn init_pp(points: &[f32], n: usize, dim: usize, k: usize, rng: &mut Pcg32)
    -> Vec<f32>
{
    let mut centroids = Vec::with_capacity(k * dim);
    // first centroid: uniform random point
    let first = rng.next_bounded(n as u32) as usize;
    centroids.extend_from_slice(&points[first * dim..(first + 1) * dim]);

    let mut d2 = vec![0.0f32; n];
    for p in 0..n {
        d2[p] = dist2(
            &points[p * dim..(p + 1) * dim],
            &centroids[0..dim],
        );
    }

    for c in 1..k {
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let next = if total <= 1e-30 {
            // all points identical / already covered: sample uniformly
            rng.next_bounded(n as u32) as usize
        } else {
            let mut target = rng.next_f64() * total;
            let mut chosen = n - 1;
            for (p, &w) in d2.iter().enumerate() {
                target -= w as f64;
                if target <= 0.0 {
                    chosen = p;
                    break;
                }
            }
            chosen
        };
        let base = centroids.len();
        centroids.extend_from_slice(&points[next * dim..(next + 1) * dim]);
        // update min-distances against the new centroid
        let newc = &centroids[base..base + dim];
        for p in 0..n {
            let d = dist2(&points[p * dim..(p + 1) * dim], newc);
            if d < d2[p] {
                d2[p] = d;
            }
        }
        let _ = c;
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated gaussian blobs in 2-D.
    fn blobs(rng: &mut Pcg32) -> Vec<f32> {
        let centers = [(-10.0f32, 0.0f32), (10.0, 0.0), (0.0, 15.0)];
        let mut pts = Vec::new();
        for &(cx, cy) in &centers {
            for _ in 0..100 {
                pts.push(cx + rng.next_f32_std() * 0.5);
                pts.push(cy + rng.next_f32_std() * 0.5);
            }
        }
        pts
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Pcg32::seed(1);
        let pts = blobs(&mut rng);
        let res = kmeans(&pts, 2, 3, 50, 1e-6, &mut rng);
        // every centroid should sit near one of the true centers
        let truth = [(-10.0f32, 0.0f32), (10.0, 0.0), (0.0, 15.0)];
        let mut matched = [false; 3];
        for c in 0..3 {
            let cx = res.centroids[c * 2];
            let cy = res.centroids[c * 2 + 1];
            for (t, &(tx, ty)) in truth.iter().enumerate() {
                if (cx - tx).abs() < 1.0 && (cy - ty).abs() < 1.0 {
                    matched[t] = true;
                }
            }
        }
        assert!(matched.iter().all(|&m| m), "centroids {:?}", res.centroids);
        // tight blobs -> tiny inertia per point
        assert!(res.inertia / 300.0 < 1.0);
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Pcg32::seed(2);
        let pts: Vec<f32> = (0..2000).map(|_| rng.next_f32_std()).collect();
        let mut last = f64::INFINITY;
        for k in [2, 8, 32] {
            let mut r = Pcg32::seed(3);
            let res = kmeans(&pts, 4, k, 30, 1e-6, &mut r);
            assert!(
                res.inertia < last,
                "inertia should shrink with k: k={k} {} >= {last}",
                res.inertia
            );
            last = res.inertia;
        }
    }

    #[test]
    fn handles_fewer_points_than_k() {
        let mut rng = Pcg32::seed(4);
        let pts = vec![1.0f32, 2.0, 3.0, 4.0]; // 2 points, dim 2
        let res = kmeans(&pts, 2, 8, 10, 1e-6, &mut rng);
        assert_eq!(res.centroids.len(), 8 * 2);
        assert!(res.centroids.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn identical_points_collapse() {
        let mut rng = Pcg32::seed(5);
        let pts = vec![5.0f32; 50 * 3];
        let res = kmeans(&pts, 3, 4, 10, 1e-6, &mut rng);
        assert!(res.inertia < 1e-9);
        for c in 0..4 {
            for d in 0..3 {
                assert!((res.centroids[c * 3 + d] - 5.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut rng = Pcg32::seed(6);
        let pts: Vec<f32> = (0..600).map(|_| rng.next_f32_std()).collect();
        let mut r1 = Pcg32::seed(7);
        let mut r2 = Pcg32::seed(7);
        let a = kmeans(&pts, 3, 5, 20, 1e-6, &mut r1);
        let b = kmeans(&pts, 3, 5, 20, 1e-6, &mut r2);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.inertia, b.inertia);
    }

    #[test]
    fn early_stop_respects_tol() {
        let mut rng = Pcg32::seed(8);
        let pts = blobs(&mut rng);
        let res = kmeans(&pts, 2, 3, 1000, 1e-3, &mut rng);
        assert!(res.iters_run < 1000, "should early-stop, ran {}",
                res.iters_run);
    }

    #[test]
    fn nearest_finds_argmin() {
        let centroids = vec![0.0f32, 0.0, 10.0, 10.0, -5.0, 2.0];
        let (idx, d) = nearest(&[9.0, 9.5], &centroids, 3, 2);
        assert_eq!(idx, 1);
        assert!((d - (1.0 + 0.25)).abs() < 1e-6);
    }
}
