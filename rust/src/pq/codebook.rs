//! Per-subspace codebook container with binary persistence.

use std::io::{Read, Write};

use anyhow::{bail, Context};

/// Codebooks for all `m` subspaces of one attention head.
///
/// Layout: `centroids[i]` is the subspace-i codebook, a flat
/// (K × d_sub) row-major matrix.
#[derive(Clone, Debug)]
pub struct Codebook {
    pub m: usize,
    pub k: usize,
    pub d_sub: usize,
    centroids: Vec<Vec<f32>>,
    /// transposed centroids per subspace: (d_sub × K) row-major. Lets the
    /// LUT build and encoder run K-wide axpy/FMA loops instead of K short
    /// dot products — the §Perf optimization (see EXPERIMENTS.md §Perf).
    centroids_t: Vec<Vec<f32>>,
    /// squared norms ‖c‖² per centroid per subspace, for the encoder's
    /// argmin ‖x−c‖² = argmax (x·c − ‖c‖²/2) trick
    norms2: Vec<Vec<f32>>,
}

impl PartialEq for Codebook {
    fn eq(&self, other: &Self) -> bool {
        self.m == other.m
            && self.k == other.k
            && self.d_sub == other.d_sub
            && self.centroids == other.centroids
    }
}

const MAGIC: &[u8; 8] = b"LOOKATCB";

/// The K values the scan kernels support: codes are u8 (or nibbles for
/// K ≤ 16), and every kernel indexes power-of-two tables. Checked at
/// every codec boundary so a corrupt or hand-edited codebook fails at
/// load with a clear error instead of mis-scanning deep inside
/// `scores_lanes`.
pub fn validate_k(k: usize) -> Result<(), String> {
    if !(2..=256).contains(&k) || !k.is_power_of_two() {
        return Err(format!(
            "k={k} centroids unsupported: K must be a power of two \
             in 2..=256"
        ));
    }
    Ok(())
}

impl Codebook {
    pub fn new(m: usize, k: usize, d_sub: usize,
               centroids: Vec<Vec<f32>>) -> Self {
        assert_eq!(centroids.len(), m);
        if let Err(e) = validate_k(k) {
            panic!("{e}");
        }
        for cb in &centroids {
            assert_eq!(cb.len(), k * d_sub);
        }
        let centroids_t: Vec<Vec<f32>> = centroids
            .iter()
            .map(|cb| {
                let mut t = vec![0.0f32; k * d_sub];
                for c in 0..k {
                    for d in 0..d_sub {
                        t[d * k + c] = cb[c * d_sub + d];
                    }
                }
                t
            })
            .collect();
        let norms2: Vec<Vec<f32>> = centroids
            .iter()
            .map(|cb| {
                (0..k)
                    .map(|c| {
                        crate::tensor::dot(
                            &cb[c * d_sub..(c + 1) * d_sub],
                            &cb[c * d_sub..(c + 1) * d_sub],
                        )
                    })
                    .collect()
            })
            .collect();
        Self { m, k, d_sub, centroids, centroids_t, norms2 }
    }

    /// Transposed (d_sub × K) centroids of subspace `i`.
    #[inline]
    pub fn subspace_t(&self, i: usize) -> &[f32] {
        &self.centroids_t[i]
    }

    /// Squared centroid norms of subspace `i`.
    #[inline]
    pub fn norms2(&self, i: usize) -> &[f32] {
        &self.norms2[i]
    }

    /// Head dimension this codebook quantizes.
    pub fn d_k(&self) -> usize {
        self.m * self.d_sub
    }

    /// Flat (K × d_sub) codebook of subspace `i`.
    #[inline]
    pub fn subspace(&self, i: usize) -> &[f32] {
        &self.centroids[i]
    }

    /// Centroid `c` of subspace `i`.
    #[inline]
    pub fn centroid(&self, i: usize, c: usize) -> &[f32] {
        &self.centroids[i][c * self.d_sub..(c + 1) * self.d_sub]
    }

    /// Storage cost of the codebooks themselves in bytes (f32 entries),
    /// i.e. the paper's "32 KB of codebook storage per layer" accounting
    /// (the paper counts FP16 entries; double for our f32 storage).
    pub fn size_bytes_f32(&self) -> usize {
        self.m * self.k * self.d_sub * 4
    }

    /// Paper-accounting size with FP16 entries (2 bytes each).
    pub fn size_bytes_fp16(&self) -> usize {
        self.m * self.k * self.d_sub * 2
    }

    // -- persistence (binary: magic, dims, then f32 LE payload) -----------

    pub fn write_to<W: Write>(&self, w: &mut W) -> anyhow::Result<()> {
        w.write_all(MAGIC)?;
        for v in [self.m as u64, self.k as u64, self.d_sub as u64] {
            w.write_all(&v.to_le_bytes())?;
        }
        for cb in &self.centroids {
            for &x in cb {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn read_from<R: Read>(r: &mut R) -> anyhow::Result<Codebook> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("codebook magic")?;
        if &magic != MAGIC {
            bail!("not a LOOKAT codebook file");
        }
        let mut b8 = [0u8; 8];
        let mut dims = [0usize; 3];
        for d in dims.iter_mut() {
            r.read_exact(&mut b8)?;
            *d = u64::from_le_bytes(b8) as usize;
        }
        let (m, k, d_sub) = (dims[0], dims[1], dims[2]);
        if m == 0 || k == 0 || d_sub == 0 || m * k * d_sub > (1 << 28) {
            bail!("unreasonable codebook dims {m}x{k}x{d_sub}");
        }
        if let Err(e) = validate_k(k) {
            bail!("corrupt codebook: {e}");
        }
        let mut centroids = Vec::with_capacity(m);
        let mut b4 = [0u8; 4];
        for _ in 0..m {
            let mut cb = Vec::with_capacity(k * d_sub);
            for _ in 0..k * d_sub {
                r.read_exact(&mut b4)?;
                cb.push(f32::from_le_bytes(b4));
            }
            centroids.push(cb);
        }
        Ok(Codebook::new(m, k, d_sub, centroids))
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        self.write_to(&mut f)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Codebook> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Self::read_from(&mut f)
    }

    /// Flatten to (m, K, d_sub) order for the PJRT artifact boundary.
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.m * self.k * self.d_sub);
        for cb in &self.centroids {
            out.extend_from_slice(cb);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn random_codebook(m: usize, k: usize, d_sub: usize) -> Codebook {
        let mut rng = Pcg32::seed(11);
        let centroids = (0..m)
            .map(|_| (0..k * d_sub).map(|_| rng.next_f32_std()).collect())
            .collect();
        Codebook::new(m, k, d_sub, centroids)
    }

    #[test]
    fn accessors_consistent() {
        let cb = random_codebook(4, 16, 8);
        assert_eq!(cb.d_k(), 32);
        assert_eq!(cb.subspace(2).len(), 16 * 8);
        assert_eq!(cb.centroid(1, 3), &cb.subspace(1)[24..32]);
    }

    #[test]
    fn size_accounting_matches_paper() {
        // paper: m=4, K=256, d_sub=16 -> 4·256·16·2 B = 32 KB per head set
        let cb = random_codebook(4, 256, 16);
        assert_eq!(cb.size_bytes_fp16(), 32 * 1024);
        assert_eq!(cb.size_bytes_f32(), 64 * 1024);
    }

    #[test]
    fn roundtrip_through_bytes() {
        let cb = random_codebook(2, 64, 4);
        let mut buf = Vec::new();
        cb.write_to(&mut buf).unwrap();
        let back = Codebook::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(back, cb);
    }

    #[test]
    fn roundtrip_through_file() {
        let cb = random_codebook(8, 32, 2);
        let dir = std::env::temp_dir().join("lookat-test-cb");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cb.bin");
        cb.save(&path).unwrap();
        let back = Codebook::load(&path).unwrap();
        assert_eq!(back, cb);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_k_panics_at_construction() {
        random_codebook(2, 17, 4);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn k_of_one_panics_at_construction() {
        random_codebook(2, 1, 4);
    }

    #[test]
    fn corrupt_k_fails_at_load_with_clear_error() {
        // hand-edit a valid file's k field to a non-power-of-two and
        // to an oversized value: both must fail in read_from, before
        // any centroid payload is trusted
        let cb = random_codebook(2, 8, 2);
        let mut buf = Vec::new();
        cb.write_to(&mut buf).unwrap();
        for bad_k in [7u64, 300] {
            let mut edited = buf.clone();
            edited[16..24].copy_from_slice(&bad_k.to_le_bytes());
            let err = Codebook::read_from(&mut edited.as_slice())
                .unwrap_err()
                .to_string();
            assert!(
                err.contains("power of two")
                    || err.contains("unreasonable"),
                "k={bad_k}: {err}"
            );
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let data = b"NOTLOOKA0000000000000000".to_vec();
        assert!(Codebook::read_from(&mut data.as_slice()).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let cb = random_codebook(2, 8, 2);
        let mut buf = Vec::new();
        cb.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 5);
        assert!(Codebook::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn to_flat_order() {
        let cb = random_codebook(3, 4, 2);
        let flat = cb.to_flat();
        assert_eq!(flat.len(), 3 * 4 * 2);
        assert_eq!(&flat[0..8], cb.subspace(0));
        assert_eq!(&flat[8..16], cb.subspace(1));
    }
}
