//! Product quantization + asymmetric distance computation — the paper's
//! §3.4/§3.5 core, implemented as the rust hot path.
//!
//! Pipeline:
//!   1. [`kmeans`] learns a per-subspace codebook from calibration keys.
//!   2. [`PqCodec`] encodes each key vector into `m` uint8 codes.
//!   3. [`LookupTable`] precomputes `LUT_i = q^(i) · C_i^T` per query and
//!      scores every key with `m` table lookups + adds — no dequantization.

mod adc;
mod codebook;
mod encoder;
pub mod kmeans;
pub mod values;

pub use adc::LookupTable;
pub use codebook::Codebook;
pub use encoder::PqCodec;

/// Number of centroids per subspace (paper fixes K = 256 so codes fit u8).
pub const NUM_CENTROIDS: usize = 256;

/// Training options for the K-Means codebook learner.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    /// Lloyd iterations.
    pub iters: usize,
    /// RNG seed (k-means++ init).
    pub seed: u64,
    /// Early-stop when relative inertia improvement falls below this.
    pub tol: f64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self { iters: 25, seed: 0x10CA7, tol: 1e-4 }
    }
}
