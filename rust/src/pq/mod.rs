//! Product quantization + asymmetric distance computation — the paper's
//! §3.4/§3.5 core, implemented as the rust hot path.
//!
//! Pipeline:
//!   1. [`kmeans`] learns a per-subspace codebook from calibration keys.
//!   2. [`PqCodec`] encodes each key vector into `m` uint8 codes.
//!   3. [`LookupTable`] precomputes `LUT_i = q^(i) · C_i^T` per query and
//!      scores every key with `m` table lookups + adds — no dequantization.
//!
//! Invariants every implementation in this module (scalar, SIMD
//! gather, nibble-packed shuffle) must preserve:
//!
//! * subspaces are accumulated **in order `0..m`** — f32 addition is
//!   not associative, and the serving engine's bit-parity tests treat
//!   any reordering as a regression;
//! * training is a pure function of (calibration keys, `d_k`, `m`,
//!   `K`, seed): identical inputs produce bit-identical codebooks, so
//!   two engines built from the same config agree on every code;
//! * `m` must divide `d_k`, and codes for `K ≤ 16` are nibble-packed
//!   ([`packs_nibbles`]) — two codes per byte, low nibble first —
//!   while larger `K` stores one byte per code.
//!
//! Codebooks are per-(layer, head): the coordinator's
//! `CompressionPolicy` may assign *different* `m` to different heads,
//! so nothing here assumes a globally uniform geometry.

mod adc;
mod codebook;
mod encoder;
pub mod kmeans;
pub mod simd;
pub mod values;

pub use adc::LookupTable;
pub use codebook::{validate_k, Codebook};
pub use encoder::PqCodec;

/// Number of centroids per subspace (paper fixes K = 256 so codes fit u8).
pub const NUM_CENTROIDS: usize = 256;

/// Whether codes for a K-centroid codebook are nibble-packed in the
/// paged cache (two 4-bit codes per byte). One rule, applied
/// everywhere: K ≤ 16 packs, larger K stores one byte per code.
pub fn packs_nibbles(k: usize) -> bool {
    k <= 16
}

/// Training options for the K-Means codebook learner.
#[derive(Clone, Debug)]
pub struct TrainOpts {
    /// Lloyd iterations.
    pub iters: usize,
    /// RNG seed (k-means++ init).
    pub seed: u64,
    /// Early-stop when relative inertia improvement falls below this.
    pub tol: f64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self { iters: 25, seed: 0x10CA7, tol: 1e-4 }
    }
}
