//! Runtime SIMD dispatch for the ADC scan and the fused value decode.
//!
//! The contract every kernel here honors: **bit-identical f32 results to
//! the scalar reference**. That is possible because all vectorization is
//! *across tokens* (one SIMD lane = one token) while the subspace loop
//! stays outer and scalar — each token still accumulates its `m`
//! partial sums strictly in order 0..m, with the exact same IEEE
//! mul/add sequence the scalar path performs. No FMA is ever used (a
//! fused `a*b+c` rounds once where the scalar path rounds twice), and
//! no reassociating horizontal reductions exist in these kernels.
//!
//! Three kernels:
//! * [`gather_accumulate`] — the K ≤ 256 byte-code lane scan: per
//!   subspace, gather `row[code[t]]` for 8 tokens at a time
//!   (`_mm256_i32gather_ps`) and add into the score lane.
//! * [`nibble_accumulate`] — the K ≤ 16 packed-lane shuffle scan: the
//!   entire quantized LUT row (16 f32) lives in two ymm registers and
//!   each lookup is a `vpermps` shuffle + blend on index bit 3 — the
//!   `pshufb` fast-scan trick at full f32 precision.
//! * [`axpy`] — the fused value decode's centroid matvec inner loop
//!   (`dst[j] += w * src[j]`, separate mul and add).
//!
//! ISA selection happens once per process ([`scan_path`]): AVX2 when
//! the CPU reports it, unless the `LOOKAT_SIMD=scalar` environment
//! variable forces the portable scalar fallback (the CI feature-matrix
//! leg runs the whole test suite that way, no rebuild needed). Scalar
//! reference implementations live here too and stay the source of
//! truth; `tests/pq_properties.rs` proves dispatched == scalar bit for
//! bit on every path.

use std::sync::OnceLock;

/// Name of the env var that forces the scalar fallback when set to
/// `scalar` (any other value is ignored).
pub const FORCE_SCALAR_ENV: &str = "LOOKAT_SIMD";

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Isa {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
}

fn isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(|| {
        if std::env::var(FORCE_SCALAR_ENV).as_deref() == Ok("scalar") {
            return Isa::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
        Isa::Scalar
    })
}

/// The active scan path, for labels and reports: `"avx2"` or
/// `"scalar"`. Resolved once per process.
pub fn scan_path() -> &'static str {
    match isa() {
        Isa::Scalar => "scalar",
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => "avx2",
    }
}

/// Whether the dispatched kernels run SIMD (false = scalar fallback,
/// either forced via [`FORCE_SCALAR_ENV`] or because the CPU lacks
/// AVX2).
pub fn simd_enabled() -> bool {
    !matches!(isa(), Isa::Scalar)
}

// ---- K ≤ 256 byte-code gather scan -------------------------------------

/// Scalar reference: `dst[t] (+)= row[codes[t]]` for one subspace.
/// `first` selects store vs accumulate (subspace 0 initializes).
#[inline]
pub fn gather_accumulate_scalar(
    row: &[f32; 256],
    codes: &[u8],
    dst: &mut [f32],
    first: bool,
) {
    debug_assert_eq!(codes.len(), dst.len());
    if first {
        for (o, &c) in dst.iter_mut().zip(codes) {
            *o = row[c as usize];
        }
    } else {
        for (o, &c) in dst.iter_mut().zip(codes) {
            *o += row[c as usize];
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gather_accumulate_avx2(
    row: &[f32; 256],
    codes: &[u8],
    dst: &mut [f32],
    first: bool,
) {
    use std::arch::x86_64::*;
    let n = codes.len();
    let table = row.as_ptr();
    let mut t = 0usize;
    while t + 8 <= n {
        // 8 token codes -> 8 i32 indices -> one 8-wide f32 gather
        let idx8 = _mm_loadl_epi64(codes.as_ptr().add(t) as *const _);
        let idx = _mm256_cvtepu8_epi32(idx8);
        let vals = _mm256_i32gather_ps::<4>(table, idx);
        let d = dst.as_mut_ptr().add(t);
        if first {
            _mm256_storeu_ps(d, vals);
        } else {
            let acc = _mm256_loadu_ps(d);
            // plain add — same single rounding as the scalar `+=`
            _mm256_storeu_ps(d, _mm256_add_ps(acc, vals));
        }
        t += 8;
    }
    gather_accumulate_scalar(
        row,
        &codes[t..],
        &mut dst[t..],
        first,
    );
}

/// Dispatched K ≤ 256 gather-accumulate (one subspace row over a
/// token-count-long code slice). Bit-identical to
/// [`gather_accumulate_scalar`] on every input.
#[inline]
pub fn gather_accumulate(
    row: &[f32; 256],
    codes: &[u8],
    dst: &mut [f32],
    first: bool,
) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            gather_accumulate_avx2(row, codes, dst, first)
        },
        Isa::Scalar => gather_accumulate_scalar(row, codes, dst, first),
    }
}

// ---- K ≤ 16 nibble-packed shuffle scan ---------------------------------

/// Extract the 4-bit code of token `t` from a packed row (low nibble =
/// even token, high nibble = odd token).
#[inline(always)]
pub fn nibble(packed: &[u8], t: usize) -> u8 {
    (packed[t / 2] >> ((t & 1) * 4)) & 0x0F
}

/// Scalar reference for the packed scan: `dst[t] (+)= row16[code4(t)]`
/// for one subspace over `len` tokens of a nibble-packed row.
#[inline]
pub fn nibble_accumulate_scalar(
    row16: &[f32; 16],
    packed: &[u8],
    len: usize,
    dst: &mut [f32],
    first: bool,
) {
    debug_assert!(len <= dst.len());
    debug_assert!(len.div_ceil(2) <= packed.len());
    if first {
        for (t, o) in dst.iter_mut().enumerate().take(len) {
            *o = row16[nibble(packed, t) as usize];
        }
    } else {
        for (t, o) in dst.iter_mut().enumerate().take(len) {
            *o += row16[nibble(packed, t) as usize];
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn nibble_accumulate_avx2(
    row16: &[f32; 16],
    packed: &[u8],
    len: usize,
    dst: &mut [f32],
    first: bool,
) {
    use std::arch::x86_64::*;
    // the whole LUT row lives in two ymm registers for the entire scan
    let lut_lo = _mm256_loadu_ps(row16.as_ptr());
    let lut_hi = _mm256_loadu_ps(row16.as_ptr().add(8));
    let seven = _mm256_set1_epi32(7);
    let lookup8 = |idx: __m256i| {
        // vpermps over entries 0–7 and 8–15, blended on index bit 3 —
        // a full-precision register-resident shuffle lookup
        let lo = _mm256_permutevar8x32_ps(lut_lo, idx);
        let hi = _mm256_permutevar8x32_ps(lut_hi, idx);
        let hi_mask = _mm256_cmpgt_epi32(idx, seven);
        _mm256_blendv_ps(lo, hi, _mm256_castsi256_ps(hi_mask))
    };
    let mut t = 0usize;
    // 16 tokens per iteration: 8 packed bytes -> 16 nibbles in token
    // order -> two 8-wide shuffle lookups
    while t + 16 <= len {
        let bytes = _mm_loadl_epi64(packed.as_ptr().add(t / 2) as *const _);
        let lo_nib = _mm_and_si128(bytes, _mm_set1_epi8(0x0F));
        let hi_nib = _mm_and_si128(
            _mm_srli_epi16(bytes, 4),
            _mm_set1_epi8(0x0F),
        );
        // interleave -> lo0,hi0,lo1,hi1,… = token order 0..16
        let toks = _mm_unpacklo_epi8(lo_nib, hi_nib);
        let idx_a = _mm256_cvtepu8_epi32(toks);
        let idx_b = _mm256_cvtepu8_epi32(_mm_srli_si128(toks, 8));
        let va = lookup8(idx_a);
        let vb = lookup8(idx_b);
        let d = dst.as_mut_ptr().add(t);
        if first {
            _mm256_storeu_ps(d, va);
            _mm256_storeu_ps(d.add(8), vb);
        } else {
            let a = _mm256_loadu_ps(d);
            let b = _mm256_loadu_ps(d.add(8));
            _mm256_storeu_ps(d, _mm256_add_ps(a, va));
            _mm256_storeu_ps(d.add(8), _mm256_add_ps(b, vb));
        }
        t += 16;
    }
    nibble_accumulate_scalar(row16, &packed[t / 2..], len - t, &mut dst[t..], first);
}

/// Dispatched K ≤ 16 packed shuffle scan. Bit-identical to
/// [`nibble_accumulate_scalar`] on every input (including odd `len`
/// partial tails, where the final byte's high nibble is ignored).
#[inline]
pub fn nibble_accumulate(
    row16: &[f32; 16],
    packed: &[u8],
    len: usize,
    dst: &mut [f32],
    first: bool,
) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            nibble_accumulate_avx2(row16, packed, len, dst, first)
        },
        Isa::Scalar => {
            nibble_accumulate_scalar(row16, packed, len, dst, first)
        }
    }
}

// ---- fused value decode matvec ----------------------------------------

/// Scalar reference: `dst[j] += w * src[j]` (separate mul then add —
/// the rounding the SIMD path must reproduce exactly).
#[inline]
pub fn axpy_scalar(dst: &mut [f32], src: &[f32], w: f32) {
    debug_assert_eq!(dst.len(), src.len());
    for (o, &v) in dst.iter_mut().zip(src) {
        *o += w * v;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(dst: &mut [f32], src: &[f32], w: f32) {
    use std::arch::x86_64::*;
    let n = dst.len();
    let wv = _mm256_set1_ps(w);
    let mut j = 0usize;
    while j + 8 <= n {
        let s = _mm256_loadu_ps(src.as_ptr().add(j));
        let d = _mm256_loadu_ps(dst.as_ptr().add(j));
        // mul then add, NOT fma: element-wise identical to the scalar
        // `*o += w * v` double rounding
        let prod = _mm256_mul_ps(wv, s);
        _mm256_storeu_ps(dst.as_mut_ptr().add(j), _mm256_add_ps(d, prod));
        j += 8;
    }
    axpy_scalar(&mut dst[j..], &src[j..], w);
}

/// Dispatched axpy for the centroid matvec phase of the fused value
/// decode. Bit-identical to [`axpy_scalar`].
#[inline]
pub fn axpy(dst: &mut [f32], src: &[f32], w: f32) {
    match isa() {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe { axpy_avx2(dst, src, w) },
        Isa::Scalar => axpy_scalar(dst, src, w),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn scan_path_is_stable_and_known() {
        let p = scan_path();
        assert!(p == "avx2" || p == "scalar", "unexpected path {p}");
        assert_eq!(p, scan_path(), "path must be resolved once");
        assert_eq!(simd_enabled(), p != "scalar");
    }

    #[test]
    fn gather_dispatch_matches_scalar_bitwise() {
        let mut rng = Pcg32::seed(0x51D);
        let mut row = [0.0f32; 256];
        for v in row.iter_mut() {
            *v = rng.next_f32_std();
        }
        // lengths straddling the 8-wide vector boundary
        for n in [0usize, 1, 7, 8, 9, 16, 31, 100] {
            let codes: Vec<u8> =
                (0..n).map(|_| rng.next_bounded(256) as u8).collect();
            let mut a = vec![0.3f32; n];
            let mut b = a.clone();
            for first in [true, false] {
                gather_accumulate(&row, &codes, &mut a, first);
                gather_accumulate_scalar(&row, &codes, &mut b, first);
                assert_eq!(
                    a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "n={n} first={first}"
                );
            }
        }
    }

    #[test]
    fn nibble_dispatch_matches_scalar_bitwise() {
        let mut rng = Pcg32::seed(0x4B17);
        let mut row = [0.0f32; 16];
        for v in row.iter_mut() {
            *v = rng.next_f32_std();
        }
        // odd lens exercise the ignored trailing high nibble
        for len in [0usize, 1, 2, 3, 15, 16, 17, 31, 32, 33, 77] {
            let packed: Vec<u8> = (0..len.div_ceil(2))
                .map(|_| rng.next_bounded(256) as u8)
                .collect();
            let mut a = vec![0.7f32; len];
            let mut b = a.clone();
            for first in [true, false] {
                nibble_accumulate(&row, &packed, len, &mut a, first);
                nibble_accumulate_scalar(
                    &row, &packed, len, &mut b, first,
                );
                assert_eq!(
                    a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "len={len} first={first}"
                );
            }
        }
    }

    #[test]
    fn nibble_order_is_low_then_high() {
        // byte 0xBA holds token0 = 0xA (low), token1 = 0xB (high)
        assert_eq!(nibble(&[0xBA], 0), 0x0A);
        assert_eq!(nibble(&[0xBA], 1), 0x0B);
        let mut row = [0.0f32; 16];
        for (i, v) in row.iter_mut().enumerate() {
            *v = i as f32;
        }
        let mut out = [0.0f32; 2];
        nibble_accumulate_scalar(&row, &[0xBA], 2, &mut out, true);
        assert_eq!(out, [10.0, 11.0]);
    }

    #[test]
    fn axpy_dispatch_matches_scalar_bitwise() {
        let mut rng = Pcg32::seed(0xA21);
        for n in [0usize, 1, 7, 8, 9, 33] {
            let src: Vec<f32> =
                (0..n).map(|_| rng.next_f32_std()).collect();
            let mut a: Vec<f32> =
                (0..n).map(|_| rng.next_f32_std()).collect();
            let mut b = a.clone();
            let w = rng.next_f32_std();
            axpy(&mut a, &src, w);
            axpy_scalar(&mut b, &src, w);
            assert_eq!(
                a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                b.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "n={n}"
            );
        }
    }
}
