//! Value compression — the paper's §5.2 future-work extension, built out.
//!
//! Keys use ADC because attention only needs score *rankings*. Values
//! enter a weighted sum, which the paper calls "non-trivial". The trick
//! is to transpose the aggregation: with PQ-coded values,
//!
//!   o = Σ_l α_l · v_l ≈ Σ_l α_l · decode(codes_l)
//!     = Σ_i Σ_c ( Σ_{l : codes_l[i]=c} α_l ) · C_i[c]
//!
//! i.e. scatter-accumulate the attention weights into a per-subspace
//! (K,) weight table, then take one (K × d_sub) matvec per subspace.
//! Cost: O(L·m + m·K·d_sub) instead of O(L·d_k) — the same complexity
//! shape as key-side ADC, and the values are never dequantized per-token.

use super::encoder::PqCodec;
use super::simd;

/// Weighted-sum of PQ-coded values via weight aggregation.
///
/// `weights` (n) are the post-softmax attention weights; `codes` is the
/// (n × m) u8 code matrix of the values. Returns the (d_k) output.
pub fn weighted_decode(
    weights: &[f32],
    codes: &[u8],
    codec: &PqCodec,
) -> Vec<f32> {
    let cb = &codec.codebook;
    let (m, k) = (cb.m, cb.k);
    let n = weights.len();
    assert_eq!(codes.len(), n * m, "codes/weights length mismatch");

    // phase 1: scatter weights into per-subspace accumulators — O(n·m)
    let pool = crate::util::threadpool::scratch();
    let mut acc = pool.take_f32(m * k);
    scatter_weights(&mut acc, weights, codes, m, k);
    let out = centroid_matvec(&acc, codec, false);
    pool.put_f32(acc);
    out
}

/// Subspace-major sibling of [`weighted_decode`] — the serving hot
/// path's fused tail, in the same fast-scan lane layout the key-side
/// ADC scan uses ([`crate::pq::LookupTable::scores_lanes`]). Each lane
/// is the `(m × stride)` code matrix of one group of tokens
/// (`BlockView::value_codes`), first `len` of each row valid, aligned
/// with `weights` in token order. One (K,) accumulator row stays hot
/// per subspace while the group's weights scatter into it; a final
/// m × K × d_sub centroid matvec produces the output — values are
/// never gathered into contiguous scratch and never dequantized per
/// token. For every accumulator cell the weight additions happen in
/// token order exactly as the flat path performs them, so the result
/// is bit-identical to [`weighted_decode`] over the gathered
/// equivalent.
///
/// Lane geometry is checked with release-mode asserts (a corrupt block
/// lane aborts instead of silently mis-weighting).
pub fn weighted_decode_lanes<'a, I>(
    weights: &[f32],
    lanes: I,
    codec: &PqCodec,
) -> Vec<f32>
where
    I: IntoIterator<Item = (&'a [u8], usize)>,
{
    weighted_decode_lanes_impl(weights, lanes, codec, false)
}

/// [`weighted_decode_lanes`] pinned to the scalar centroid matvec,
/// regardless of detected ISA — the bit-identity reference for
/// property tests and benches.
pub fn weighted_decode_lanes_scalar<'a, I>(
    weights: &[f32],
    lanes: I,
    codec: &PqCodec,
) -> Vec<f32>
where
    I: IntoIterator<Item = (&'a [u8], usize)>,
{
    weighted_decode_lanes_impl(weights, lanes, codec, true)
}

fn weighted_decode_lanes_impl<'a, I>(
    weights: &[f32],
    lanes: I,
    codec: &PqCodec,
    force_scalar: bool,
) -> Vec<f32>
where
    I: IntoIterator<Item = (&'a [u8], usize)>,
{
    let cb = &codec.codebook;
    let (m, k) = (cb.m, cb.k);
    let pool = crate::util::threadpool::scratch();
    let mut acc = pool.take_f32(m * k);
    let mut l = 0usize;
    for (lane, len) in lanes {
        assert_eq!(
            lane.len() % m,
            0,
            "value-code lane misaligned: {} bytes for m={m}",
            lane.len()
        );
        let stride = lane.len() / m;
        assert!(
            len <= stride,
            "lane claims {len} tokens but has stride {stride}"
        );
        let w = &weights[l..l + len];
        for i in 0..m {
            let accrow = &mut acc[i * k..(i + 1) * k];
            let codes_i = &lane[i * stride..i * stride + len];
            for (&c, &wv) in codes_i.iter().zip(w) {
                if wv != 0.0 {
                    accrow[c as usize] += wv;
                }
            }
        }
        l += len;
    }
    assert_eq!(l, weights.len(), "codes/weights length mismatch");
    let out = centroid_matvec(&acc, codec, force_scalar);
    pool.put_f32(acc);
    out
}

/// Nibble-packed sibling of [`weighted_decode_lanes`] for K ≤ 16
/// codecs: each lane row holds `stride` bytes = two 4-bit codes per
/// byte (low nibble = even token), so a lane of `m × stride` bytes
/// covers up to `2·stride` tokens. The scatter unpacks nibbles in
/// token order, preserving the flat path's accumulation order cell by
/// cell — bit-identical to [`weighted_decode`] over the gathered,
/// unpacked equivalent.
pub fn weighted_decode_lanes_packed<'a, I>(
    weights: &[f32],
    lanes: I,
    codec: &PqCodec,
) -> Vec<f32>
where
    I: IntoIterator<Item = (&'a [u8], usize)>,
{
    weighted_decode_lanes_packed_impl(weights, lanes, codec, false)
}

/// [`weighted_decode_lanes_packed`] pinned to the scalar centroid
/// matvec — the reference path for dispatch-identity tests.
pub fn weighted_decode_lanes_packed_scalar<'a, I>(
    weights: &[f32],
    lanes: I,
    codec: &PqCodec,
) -> Vec<f32>
where
    I: IntoIterator<Item = (&'a [u8], usize)>,
{
    weighted_decode_lanes_packed_impl(weights, lanes, codec, true)
}

fn weighted_decode_lanes_packed_impl<'a, I>(
    weights: &[f32],
    lanes: I,
    codec: &PqCodec,
    force_scalar: bool,
) -> Vec<f32>
where
    I: IntoIterator<Item = (&'a [u8], usize)>,
{
    let cb = &codec.codebook;
    let (m, k) = (cb.m, cb.k);
    assert!(
        super::packs_nibbles(k),
        "packed decode needs K <= 16 (4-bit codes); this codec has K={k}"
    );
    let pool = crate::util::threadpool::scratch();
    let mut acc = pool.take_f32(m * k);
    let mut l = 0usize;
    for (lane, len) in lanes {
        assert_eq!(
            lane.len() % m,
            0,
            "packed value-code lane misaligned: {} bytes for m={m}",
            lane.len()
        );
        let stride = lane.len() / m;
        assert!(
            len <= 2 * stride,
            "packed lane claims {len} tokens but holds at most {}",
            2 * stride
        );
        let w = &weights[l..l + len];
        for i in 0..m {
            let accrow = &mut acc[i * k..(i + 1) * k];
            let packed_i = &lane[i * stride..(i + 1) * stride];
            for (t, &wv) in w.iter().enumerate() {
                if wv != 0.0 {
                    accrow[simd::nibble(packed_i, t) as usize] += wv;
                }
            }
        }
        l += len;
    }
    assert_eq!(l, weights.len(), "codes/weights length mismatch");
    let out = centroid_matvec(&acc, codec, force_scalar);
    pool.put_f32(acc);
    out
}

/// Phase 1 of the transposed aggregation: `acc[i*k + codes[l][i]] +=
/// weights[l]` for every token `l` of one token-major code chunk.
fn scatter_weights(
    acc: &mut [f32],
    weights: &[f32],
    codes: &[u8],
    m: usize,
    k: usize,
) {
    for (l, &w) in weights.iter().enumerate() {
        if w == 0.0 {
            continue;
        }
        let row = &codes[l * m..(l + 1) * m];
        for (i, &c) in row.iter().enumerate() {
            acc[i * k + c as usize] += w;
        }
    }
}

/// Phase 2: per-subspace weighted centroid sum — O(m·K·d_sub). The
/// output buffer is drawn from the shared scratch pool so the serving
/// loop can recycle it once the context vector is consumed. The inner
/// axpy dispatches to the SIMD kernel (mul-then-add, never FMA, so the
/// scalar path stays bit-identical).
fn centroid_matvec(
    acc: &[f32],
    codec: &PqCodec,
    force_scalar: bool,
) -> Vec<f32> {
    let cb = &codec.codebook;
    let (m, k, d_sub) = (cb.m, cb.k, cb.d_sub);
    let mut out = crate::util::threadpool::scratch().take_f32(m * d_sub);
    for i in 0..m {
        let seg = &mut out[i * d_sub..(i + 1) * d_sub];
        let cents = cb.subspace(i);
        for c in 0..k {
            let w = acc[i * k + c];
            if w != 0.0 {
                let cent = &cents[c * d_sub..(c + 1) * d_sub];
                if force_scalar {
                    simd::axpy_scalar(seg, cent, w);
                } else {
                    simd::axpy(seg, cent, w);
                }
            }
        }
    }
    out
}

/// Analytic FLOP count of [`weighted_decode`] vs the dense reduction.
pub fn flops(n: usize, m: usize, k: usize, d_sub: usize) -> (usize, usize) {
    let dense = n * m * d_sub; // Σ α_l·v_l over d_k = m·d_sub dims
    let adc = n * m + m * k * d_sub;
    (dense, adc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::TrainOpts;
    use crate::testkit::fixtures::interleave_lanes;
    use crate::util::rng::Pcg32;

    fn setup(n: usize, d_k: usize, m: usize, k: usize)
        -> (Vec<f32>, PqCodec, Vec<u8>, Vec<f32>)
    {
        let mut rng = Pcg32::seed(0xBEEF);
        let values: Vec<f32> =
            (0..n * d_k).map(|_| rng.next_f32_std()).collect();
        let codec = PqCodec::train(&values, d_k, m, k,
                                   &TrainOpts::default());
        let codes = codec.encode_batch(&values, n);
        let mut weights: Vec<f32> =
            (0..n).map(|_| rng.next_f32()).collect();
        let s: f32 = weights.iter().sum();
        for w in weights.iter_mut() {
            *w /= s;
        }
        (values, codec, codes, weights)
    }

    /// dense oracle: Σ α_l · decode(codes_l)
    fn oracle(weights: &[f32], codes: &[u8], codec: &PqCodec) -> Vec<f32> {
        let m = codec.codebook.m;
        let d_k = codec.codebook.d_k();
        let mut out = vec![0.0f32; d_k];
        for (l, &w) in weights.iter().enumerate() {
            let v = codec.decode(&codes[l * m..(l + 1) * m]);
            for (o, x) in out.iter_mut().zip(&v) {
                *o += w * x;
            }
        }
        out
    }

    #[test]
    fn matches_dense_decode_reduction() {
        for (n, m, k) in [(64, 4, 32), (200, 8, 64), (128, 2, 256)] {
            let (_, codec, codes, weights) = setup(n, 64, m, k);
            let got = weighted_decode(&weights, &codes, &codec);
            let want = oracle(&weights, &codes, &codec);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "n={n} m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn close_to_uncompressed_values() {
        let (values, codec, codes, weights) = setup(256, 64, 8, 256);
        let approx = weighted_decode(&weights, &codes, &codec);
        let mut exact = vec![0.0f32; 64];
        for (l, &w) in weights.iter().enumerate() {
            for (o, x) in exact.iter_mut().zip(&values[l * 64..(l + 1) * 64])
            {
                *o += w * x;
            }
        }
        let cos = crate::metrics::cosine_similarity(&exact, &approx);
        assert!(cos > 0.95, "cosine {cos}");
    }

    #[test]
    fn zero_weights_give_zero_output() {
        let (_, codec, codes, _) = setup(32, 32, 4, 16);
        let out = weighted_decode(&vec![0.0; 32], &codes, &codec);
        assert!(out.iter().all(|&x| x == 0.0));
        // lane path agrees on the all-zero weight vector
        let lanes = interleave_lanes(&codes, 4, 8);
        let blocked = weighted_decode_lanes(
            &vec![0.0; 32],
            lanes.iter().map(|(l, n)| (&l[..], *n)),
            &codec,
        );
        assert_eq!(out, blocked);
    }

    #[test]
    fn empty_weights_give_zero_output_of_full_dim() {
        let (_, codec, _, _) = setup(8, 32, 4, 16);
        let out = weighted_decode(&[], &[], &codec);
        assert_eq!(out, vec![0.0f32; 32]);
        let blocked =
            weighted_decode_lanes(&[], std::iter::empty(), &codec);
        assert_eq!(blocked, vec![0.0f32; 32]);
    }

    #[test]
    fn lane_decode_bit_identical_to_flat() {
        for (n, m, k) in [(64usize, 4usize, 32usize), (200, 8, 64)] {
            let (_, codec, codes, weights) = setup(n, 64, m, k);
            let flat = weighted_decode(&weights, &codes, &codec);
            // uneven group sizes incl. a partial tail — the paged shape
            for gt in [32usize, 48, 7, n] {
                let lanes = interleave_lanes(&codes, m, gt);
                let blocked = weighted_decode_lanes(
                    &weights,
                    lanes.iter().map(|(l, n)| (&l[..], *n)),
                    &codec,
                );
                assert_eq!(
                    flat.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    blocked.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "n={n} m={m} group_tokens={gt}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn lanes_reject_short_code_stream() {
        let (_, codec, codes, weights) = setup(32, 32, 4, 16);
        // stream only half the lanes for a full-length weight vector
        let lanes = interleave_lanes(&codes, 4, 16);
        weighted_decode_lanes(
            &weights,
            lanes.iter().take(1).map(|(l, n)| (&l[..], *n)),
            &codec,
        );
    }

    #[test]
    #[should_panic(expected = "misaligned")]
    fn lanes_reject_misaligned_lane_in_release_too() {
        let (_, codec, _, _) = setup(8, 32, 4, 16);
        weighted_decode_lanes(&[0.1], [(&[0u8; 7][..], 1)], &codec);
    }

    #[test]
    fn single_hot_weight_reconstructs_that_value() {
        let (_, codec, codes, _) = setup(32, 32, 4, 16);
        let mut w = vec![0.0f32; 32];
        w[7] = 1.0;
        let out = weighted_decode(&w, &codes, &codec);
        let want = codec.decode(&codes[7 * 4..8 * 4]);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn flops_favor_adc_for_long_caches() {
        // at L=512, d_k=64, m=4, K=256: dense = 32768, adc = 2048+16384
        let (dense, adc) = flops(512, 4, 256, 16);
        assert_eq!(dense, 512 * 64);
        assert_eq!(adc, 512 * 4 + 4 * 256 * 16);
        // crossover: ADC wins once n·m·d_sub > n·m + m·K·d_sub
        let (d2, a2) = flops(4096, 4, 256, 16);
        assert!(a2 < d2);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn rejects_mismatched_inputs() {
        let (_, codec, codes, _) = setup(32, 32, 4, 16);
        weighted_decode(&vec![0.1; 16], &codes, &codec);
    }

    #[test]
    fn packed_lane_decode_bit_identical_to_flat_for_every_m() {
        use crate::testkit::fixtures::interleave_lanes_packed;
        for m in [2usize, 4, 8, 16] {
            let (_, codec, codes, weights) = setup(200, 64, m, 16);
            assert!(codec.packed());
            let flat = weighted_decode(&weights, &codes, &codec);
            // uneven groups, a partial tail, and one odd-length group
            for gt in [32usize, 48, 6, 200] {
                let lanes = interleave_lanes_packed(&codes, m, gt);
                for scalar in [false, true] {
                    let it = lanes.iter().map(|(l, n)| (&l[..], *n));
                    let got = if scalar {
                        weighted_decode_lanes_packed_scalar(
                            &weights, it, &codec,
                        )
                    } else {
                        weighted_decode_lanes_packed(&weights, it, &codec)
                    };
                    assert_eq!(
                        flat.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        "m={m} group_tokens={gt} scalar={scalar}"
                    );
                }
            }
        }
    }

    #[test]
    fn packed_decode_honors_odd_truncation() {
        use crate::testkit::fixtures::interleave_lanes_packed;
        let (_, codec, codes, weights) = setup(100, 64, 4, 16);
        for cut in [31usize, 32, 33, 45, 64, 65] {
            let flat =
                weighted_decode(&weights[..cut], &codes[..cut * 4], &codec);
            // truncate the lane stream mid-block, odd cuts included
            let lanes = interleave_lanes_packed(&codes, 4, 32);
            let mut left = cut;
            let it = lanes.iter().filter_map(|(l, n)| {
                if left == 0 {
                    return None;
                }
                let take = (*n).min(left);
                left -= take;
                Some((&l[..], take))
            });
            let got =
                weighted_decode_lanes_packed(&weights[..cut], it, &codec);
            assert_eq!(
                flat.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn lane_decode_dispatch_matches_scalar_bitwise() {
        use crate::testkit::fixtures::interleave_lanes;
        let (_, codec, codes, weights) = setup(203, 64, 8, 64);
        let lanes = interleave_lanes(&codes, 8, 32);
        let simd = weighted_decode_lanes(
            &weights,
            lanes.iter().map(|(l, n)| (&l[..], *n)),
            &codec,
        );
        let scalar = weighted_decode_lanes_scalar(
            &weights,
            lanes.iter().map(|(l, n)| (&l[..], *n)),
            &codec,
        );
        assert_eq!(
            simd.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            scalar.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    #[should_panic(expected = "needs K <= 16")]
    fn packed_decode_rejects_wide_codebooks() {
        let (_, codec, _, _) = setup(8, 32, 4, 64);
        weighted_decode_lanes_packed(
            &[0.5],
            [(&[0u8; 8][..], 1)],
            &codec,
        );
    }

    #[test]
    #[should_panic(expected = "holds at most")]
    fn packed_decode_rejects_overlong_len() {
        let (_, codec, _, _) = setup(8, 32, 4, 16);
        // 8 bytes / m=4 -> stride 2 -> max 4 tokens, claim 5
        weighted_decode_lanes_packed(
            &[0.2; 5],
            [(&[0u8; 8][..], 5)],
            &codec,
        );
    }
}
