//! API-compatible **stub** of the small xla-rs / PJRT surface that
//! `lookat::runtime::executor` uses.
//!
//! The offline build image does not vendor the real `xla` crate (it links
//! a multi-hundred-MB xla_extension). This stub keeps `--features xla`
//! *compiling* everywhere; every runtime entry point returns an error
//! telling the operator to patch in a real checkout:
//!
//! ```toml
//! # .cargo/config.toml or workspace Cargo.toml
//! [patch.crates-io]            # or a [patch."path"] override
//! xla = { path = "/path/to/xla-rs" }
//! ```
//!
//! Keep the type/method signatures in sync with
//! `rust/src/runtime/executor.rs` — that file is the single consumer.

use std::fmt;
use std::path::Path;

/// Stub error: carries a static explanation.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this build links the vendored xla *stub*; patch the \
         real xla-rs crate in to execute HLO (see rust/README.md)"
    )))
}

/// Element types a [`Literal`] can hold in this stub.
pub trait NativeElem: Copy {}
impl NativeElem for f32 {}
impl NativeElem for i32 {}

/// Host-side tensor literal (stub: stores nothing).
#[derive(Clone, Debug, Default)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// 1-D literal from a host slice.
    pub fn vec1<T: NativeElem>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _priv: () })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub_err("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeElem>(&self) -> Result<Vec<T>> {
        stub_err("Literal::to_vec")
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        stub_err("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device-side buffer returned by an execution (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub_err("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

/// Literal-like argument types accepted by [`PjRtLoadedExecutable::execute`].
pub trait AsLiteral {}
impl AsLiteral for Literal {}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsLiteral>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub_err("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client (stub: construction fails loudly so no one mistakes the
/// stub for a working runtime).
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub_err("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        stub_err("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_construction_is_cheap() {
        let l = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
